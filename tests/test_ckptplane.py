"""Checkpoint plane v2: delta encoding, tiering and their crash paths.

Delta-encoded commits chain child→parent, the tiered backend moves blobs
between disk and a remote object store underneath readers, and the
write-behind layer lets evictions race in-flight commits — this file
covers the interleavings where those three mechanisms meet: an eviction
landing while a delta is being serialized, a delta whose parent has been
demoted off the local disk, chains hitting the rebase depth bound, and
snapshot/restore identity over a tiered store.
"""

import os
import threading
import types

import numpy as np
import pytest

from repro.core import SearchPlanDB, StudyService, StudySpec
from repro.core.hpseq import Constant, MultiStep
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridSearchSpace, GridTuner
from repro.train import checkpoint as ckpt_mod
from repro.train.checkpoint import (CheckpointStore, DirectoryObjectStore,
                                    ObjectStore)


def big_tree(i: int, mutate_from=None, frac: float = 0.25):
    """~1 MB two-leaf state; with ``mutate_from``, only the leading
    ``frac`` of the big leaf differs (a stage advancing part of a model)."""
    if mutate_from is None:
        rng = np.random.default_rng(i)
        w = rng.standard_normal(250_000).astype(np.float32)
    else:
        w = mutate_from["w"].copy()
        n = int(len(w) * frac)
        w[:n] += np.float32(1 + i)
    return {"w": w, "step": np.int64(i)}


def assert_tree_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    assert int(a["step"]) == int(b["step"])


# ---------------------------------------------------------------------------
# delta encoding
# ---------------------------------------------------------------------------


def test_delta_commit_writes_less_and_restores_identically(tmp_path):
    store = CheckpointStore(str(tmp_path))
    base = big_tree(0)
    cid0 = store.put("pk", 10, base)
    full_written = store.bytes_written
    child = big_tree(1, mutate_from=base)
    cid1 = store.put("pk", 20, child, parent_cid=cid0)
    delta_written = store.bytes_written - full_written

    assert store.full_commits == 1 and store.delta_commits == 1
    # 25% of one leaf mutated -> the delta is a small fraction of the full
    assert delta_written < full_written / 2
    assert store.dedup_ratio > 1.3

    store._read_cache.clear()
    assert_tree_equal(store.get(cid1), child)
    assert_tree_equal(store.get(cid0), base)


def test_fully_divergent_child_falls_back_to_full(tmp_path):
    """A child sharing no chunk with its parent commits as a standalone
    full snapshot — no pointless zero-reference delta chain."""
    store = CheckpointStore(str(tmp_path))
    cid0 = store.put("pk", 10, big_tree(0))
    cid1 = store.put("pk", 20, big_tree(99), parent_cid=cid0)   # unrelated
    assert store.delta_commits == 0 and store.full_commits == 2
    assert store._read_header(cid1)["kind"] == "full"
    store.evict(cid0)
    store._read_cache.clear()
    assert_tree_equal(store.get(cid1), big_tree(99))   # no parent needed


def test_delta_chain_rebases_at_depth_bound(tmp_path):
    store = CheckpointStore(str(tmp_path), max_delta_depth=3)
    t = big_tree(0)
    cid = store.put("pk", 0, t)
    for i in range(1, 8):
        t = big_tree(i, mutate_from=t, frac=0.1)
        cid = store.put("pk", i * 10, t, parent_cid=cid)
    # depths walk 1,2,3 then the next child rebases to a fresh full (0)
    # and the walk restarts: 1,2,3 again — one rebase over 7 children
    assert store.delta_rebases == 1
    assert store.full_commits == 2          # the root + one rebase
    assert store._read_header(cid)["depth"] <= 3
    store._read_cache.clear()
    assert_tree_equal(store.get(cid), t)    # deepest chain resolves


def test_missing_parent_meta_falls_back_to_full(tmp_path):
    """A parent cid the store cannot index (never committed here, blob
    gone) must not poison the put — the child commits full."""
    store = CheckpointStore(str(tmp_path))
    cid = store.put("pk", 10, big_tree(0), parent_cid="ghost@0")
    assert store.delta_fallbacks == 1
    assert store.full_commits == 1
    store._read_cache.clear()
    assert_tree_equal(store.get(cid), big_tree(0))


def test_delta_whose_parent_was_evicted_reads_as_missing(tmp_path):
    """Recompute-on-miss territory: resolving a delta whose parent blob is
    gone from every tier raises KeyError (not a crash, not garbage)."""
    base = big_tree(0)
    store = CheckpointStore(str(tmp_path))
    cid0 = store.put("pk", 10, base)
    cid1 = store.put("pk", 20, big_tree(1, mutate_from=base),
                     parent_cid=cid0)
    assert store.delta_commits == 1
    store.evict(cid0)
    store._read_cache.clear()
    with pytest.raises(KeyError):
        store.get(cid1)
    assert store.store_misses >= 1


# ---------------------------------------------------------------------------
# evict racing an in-flight delta commit
# ---------------------------------------------------------------------------


def test_evict_during_delta_commit_discards_the_write(monkeypatch, tmp_path):
    """An eviction landing while the writer thread serializes a delta must
    cancel the publish: no file appears, readers see a miss, and a later
    re-put of the same cid commits cleanly."""
    store = CheckpointStore(str(tmp_path))
    base = big_tree(0)
    cid0 = store.put("pk", 10, base)
    child = big_tree(1, mutate_from=base)

    in_serialize = threading.Event()
    release = threading.Event()
    real_serialize = store._serialize_disk

    def stalling_serialize(cid, tree, parent_cid=None):
        in_serialize.set()
        assert release.wait(timeout=10)
        return real_serialize(cid, tree, parent_cid)

    monkeypatch.setattr(store, "_serialize_disk", stalling_serialize)
    cid1 = store.put_async("pk", 20, child, parent_cid=cid0)
    assert in_serialize.wait(timeout=10)     # writer is mid-serialization
    assert store.evict(cid1)                 # eviction races the commit
    release.set()
    store.flush()

    assert not os.path.exists(store._path(cid1))
    assert not any(f.endswith(".tmp") for f in os.listdir(str(tmp_path)))
    with pytest.raises(KeyError):
        store.get(cid1)
    # same-content re-put after the cancelled commit publishes normally
    monkeypatch.setattr(store, "_serialize_disk", real_serialize)
    assert store.put_async("pk", 20, child, parent_cid=cid0) == cid1
    store.flush()
    store._read_cache.clear()
    assert_tree_equal(store.get(cid1), child)


# ---------------------------------------------------------------------------
# tiered backend
# ---------------------------------------------------------------------------


def test_delta_restore_with_parent_demoted_to_remote(tmp_path):
    """Resolving a delta chain whose parent blob was demoted off the local
    disk fetches the parent from the remote tier and promotes it back."""
    remote = DirectoryObjectStore(str(tmp_path / "remote"))
    store = CheckpointStore(str(tmp_path / "disk"), remote=remote,
                            disk_capacity_bytes=1_200_000)
    base = big_tree(0)
    cid0 = store.put("pk", 10, base)
    children = []
    t = base
    for i in range(1, 4):
        t = big_tree(i, mutate_from=t, frac=0.2)
        children.append((store.put("pk", 10 + i, t, parent_cid=cid0
                                   if i == 1 else children[-1][0]), t))
    # capacity pressure pushed the LRU (the full base blob) to remote
    assert store.tier_demotions >= 1
    assert remote.contains(cid0)
    assert not os.path.exists(store._path(cid0))

    store._read_cache.clear()
    cid_last, t_last = children[-1]
    assert_tree_equal(store.get(cid_last), t_last)     # chain via remote
    assert store.remote_hits + store.tier_promotions >= 1
    assert store.remote_bytes_read > 0


def test_eviction_removes_remote_replica(tmp_path):
    remote = DirectoryObjectStore(str(tmp_path / "remote"))
    store = CheckpointStore(str(tmp_path / "disk"), remote=remote,
                            disk_capacity_bytes=1)     # demote everything
    cid = store.put("pk", 10, big_tree(0))
    store.put("pk", 20, big_tree(1))                   # pressure: 10 demotes
    if not remote.contains(cid):                       # ordering safety
        store._demote_excess()
    assert store.evict(cid)
    assert not remote.contains(cid)
    assert cid not in store.committed_ids()


def test_reopened_store_indexes_remote_tier(tmp_path):
    """A fresh store over the same tiers serves blobs that only exist
    remotely — the committed index unions both tiers, no directory scan
    of the remote needed beyond attach-time keys()."""
    remote = DirectoryObjectStore(str(tmp_path / "remote"))
    store = CheckpointStore(str(tmp_path / "disk"), remote=remote,
                            disk_capacity_bytes=600_000)
    cids = [store.put("pk", i, big_tree(i)) for i in range(3)]
    assert store.tier_demotions >= 2

    reopened = CheckpointStore(str(tmp_path / "disk"), remote=remote)
    assert set(cids) <= reopened.committed_ids()
    assert len(reopened) == 3
    for i, cid in enumerate(cids):
        assert reopened.contains(cid)
        assert_tree_equal(reopened.get(cid), big_tree(i))


class FlakyRemote(ObjectStore):
    """Remote whose blobs vanish (external lifecycle policy)."""

    def __init__(self):
        self.blobs = {}

    def put(self, key, data):
        self.blobs[key] = data

    def get(self, key):
        if key not in self.blobs:
            raise KeyError(key)
        return self.blobs[key]

    def delete(self, key):
        del self.blobs[key]

    def contains(self, key):
        return key in self.blobs

    def keys(self):
        return list(self.blobs)


def test_remote_losing_blobs_degrades_to_key_error(tmp_path):
    remote = FlakyRemote()
    store = CheckpointStore(str(tmp_path), remote=remote,
                            disk_capacity_bytes=1)
    cid = store.put("pk", 10, big_tree(0))
    store.put("pk", 20, big_tree(1))
    assert remote.contains(cid)
    remote.blobs.clear()                   # lifecycle policy reaped it
    store._read_cache.clear()
    with pytest.raises(KeyError):
        store.get(cid)


def test_legacy_format_blob_degrades_to_miss(tmp_path):
    """A pre-v2 blob at a probed path reads as missing (recompute-on-miss
    upstream), never as garbage or a crash."""
    store = CheckpointStore(str(tmp_path))
    cid = store.ckpt_id("pk", 10)
    with open(store._path(cid), "wb") as f:
        f.write(b"PK\x03\x04 this is not a v2 blob" * 10)
    reopened = CheckpointStore(str(tmp_path))
    assert reopened.contains(cid)          # indexed by extension...
    with pytest.raises(KeyError):
        reopened.get(cid)                  # ...but unreadable -> miss


# ---------------------------------------------------------------------------
# tiering under faults: remote outages, evict/demote races, writer survival
# ---------------------------------------------------------------------------


class FailingPutRemote(FlakyRemote):
    """Remote whose uploads fail (an outage) until ``healed`` is set."""

    def __init__(self):
        super().__init__()
        self.healed = False

    def put(self, key, data):
        if not self.healed:
            raise OSError("remote tier unavailable")
        super().put(key, data)


def test_failed_demotion_put_does_not_kill_writer(tmp_path):
    """A remote.put outage during background demotion must not kill the
    writer thread: pending writes keep committing, flush() returns (no
    deadlock), the blob stays readable locally, and the outage is
    counted — demotion resumes once the remote heals."""
    remote = FailingPutRemote()
    store = CheckpointStore(str(tmp_path), remote=remote,
                            disk_capacity_bytes=1)
    cids = [store.put_async("pk", i * 10, big_tree(i)) for i in range(3)]
    store.flush()                      # would deadlock behind a dead writer
    assert store.tier_demotion_errors >= 1
    assert store.tier_demotions == 0
    for i, cid in enumerate(cids):     # everything still served locally
        store._read_cache.clear()
        assert_tree_equal(store.get(cid), big_tree(i))
    remote.healed = True
    store._demote_excess()             # outage over: demotion resumes
    assert store.tier_demotions >= 1


def test_writer_thread_death_is_survivable(monkeypatch, tmp_path):
    """An exception escaping the writer-loop body (here: an exploding
    post-commit demotion hook) must clear the dead thread's slot —
    flush() surfaces the error, and the next put_async gets a fresh
    writer instead of queueing forever behind a corpse."""
    store = CheckpointStore(str(tmp_path))
    monkeypatch.setattr(
        store, "_demote_excess",
        lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    store.put_async("pk", 10, big_tree(0))
    writer = store._writer             # None if it already died and cleared
    if writer is not None:
        writer.join(timeout=10)
        assert not writer.is_alive()   # the hook killed the thread
    with pytest.raises(RuntimeError):
        store.flush()
    monkeypatch.setattr(store, "_demote_excess", lambda: None)
    cid = store.put_async("pk", 20, big_tree(1))
    store.flush()                      # a replacement writer committed it
    store._read_cache.clear()
    assert_tree_equal(store.get(cid), big_tree(1))


def test_evict_during_demotion_does_not_resurrect(tmp_path):
    """evict() landing while the demotion upload is in flight wins: the
    freshly uploaded remote copy is deleted instead of indexed, so the
    evicted checkpoint never reappears in committed_ids()/get()."""
    uploading = threading.Event()
    release = threading.Event()

    class StallingRemote(FlakyRemote):
        def put(self, key, data):
            uploading.set()
            assert release.wait(timeout=10)
            super().put(key, data)

    remote = StallingRemote()
    store = CheckpointStore(str(tmp_path), remote=remote,
                            disk_capacity_bytes=1)
    cid0 = store.put("pk", 10, big_tree(0))
    # the second commit pushes past capacity and demotes cid0 (the LRU);
    # run it on a helper thread so the eviction can land mid-upload
    t = threading.Thread(target=store.put, args=("pk", 20, big_tree(1)))
    t.start()
    assert uploading.wait(timeout=10)          # upload in flight
    assert store.evict(cid0)                   # eviction races it
    release.set()
    t.join(timeout=10)
    assert not t.is_alive()
    assert not remote.contains(cid0)           # upload was rolled back
    assert cid0 not in store.committed_ids()
    with pytest.raises(KeyError):
        store.get(cid0)
    assert store.tier_demotions == 0           # rolled back, not counted


# ---------------------------------------------------------------------------
# read-path sharing and re-chunked reopen
# ---------------------------------------------------------------------------


def test_restored_trees_are_read_only_and_cache_safe(tmp_path):
    """get() shares one reconstruction through the read cache, so
    disk-restored leaves are enforced read-only — in-place mutation
    raises instead of silently corrupting what the next get() serves."""
    store = CheckpointStore(str(tmp_path))
    base = big_tree(0)
    cid = store.put("pk", 10, base)
    store._read_cache.clear()
    restored = store.get(cid)
    assert restored["w"].flags.writeable is False
    with pytest.raises(ValueError):
        restored["w"][:10] = 0.0
    assert_tree_equal(store.get(cid), base)    # cached copy unharmed


def test_chunk_size_change_degrades_delta_to_full(tmp_path):
    """A store reopened with a different chunk_bytes must not delta
    against blobs chunked at the old size (same digest index, different
    byte range — splicing would corrupt silently): the child falls back
    to a full commit and restores bit-identically."""
    base = big_tree(0)
    store = CheckpointStore(str(tmp_path), chunk_bytes=1 << 16)
    cid0 = store.put("pk", 10, base)
    assert store._read_header(cid0)["chunk"] == 1 << 16

    reopened = CheckpointStore(str(tmp_path), chunk_bytes=1 << 14)
    child = big_tree(1, mutate_from=base)
    cid1 = reopened.put("pk", 20, child, parent_cid=cid0)
    assert reopened.delta_fallbacks == 1
    assert reopened.full_commits == 1 and reopened.delta_commits == 0
    reopened._read_cache.clear()
    assert_tree_equal(reopened.get(cid1), child)
    assert_tree_equal(reopened.get(cid0), base)


# ---------------------------------------------------------------------------
# process-pool serializer
# ---------------------------------------------------------------------------


def test_process_pool_serializer_matches_inline(tmp_path):
    base = big_tree(0)
    child = big_tree(1, mutate_from=base)
    inline = CheckpointStore(str(tmp_path / "a"))
    pooled = CheckpointStore(str(tmp_path / "b"), serializer_procs=1)
    try:
        for s in (inline, pooled):
            c0 = s.put("pk", 10, base)
            s.put_async("pk", 20, child, parent_cid=c0)
            s.flush()
        assert pooled.delta_commits == inline.delta_commits == 1
        # identical encoding decisions -> identical physical bytes
        assert pooled.bytes_written == inline.bytes_written
        pooled._read_cache.clear()
        assert_tree_equal(pooled.get(pooled.ckpt_id("pk", 20)), child)
    finally:
        pooled.close()
        inline.close()


# ---------------------------------------------------------------------------
# engine integration: stats mirror + tiered snapshot/restore identity
# ---------------------------------------------------------------------------

SPEC = StudySpec("m", "d", ("lr", "bs"))


def _space():
    return GridSearchSpace(
        fns={"lr": [Constant(0.1),
                    MultiStep(0.1, [60], values=[0.1, 0.01]),
                    MultiStep(0.1, [60], values=[0.1, 0.02])],
             "bs": [Constant(64)]})


def det(stats):
    import dataclasses
    return dataclasses.replace(
        stats, ckpt_save_seconds=0.0, ckpt_load_seconds=0.0,
        ckpt_delta_bytes=0, ckpt_full_bytes=0, ckpt_logical_bytes=0,
        ckpt_bytes_written=0, ckpt_delta_commits=0, ckpt_delta_rebases=0,
        ckpt_mem_hits=0, ckpt_disk_hits=0, ckpt_remote_hits=0,
        ckpt_store_misses=0, ckpt_tier_promotions=0, ckpt_tier_demotions=0,
        ckpt_tmp_reclaimed=0)


def _tiered(tmp_path, capacity=40_000):
    return CheckpointStore(
        str(tmp_path / "disk"),
        remote=DirectoryObjectStore(str(tmp_path / "remote")),
        disk_capacity_bytes=capacity)


def test_engine_stats_mirror_store_counters(tmp_path):
    # one worker: sibling resumes cross scheduling rounds, so they load
    # through the store (in-round handoff would bypass it); a tiny disk
    # capacity forces demotion traffic through the remote tier
    store = _tiered(tmp_path, capacity=500)
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(), n_workers=1, store=store)
    svc.submit(SPEC, GridTuner(_space().trials(120)))
    stats = svc.close()
    assert stats.ckpt_bytes_written == store.bytes_written > 0
    assert stats.ckpt_delta_commits == store.delta_commits
    assert stats.ckpt_tier_demotions == store.tier_demotions
    assert (stats.ckpt_mem_hits + stats.ckpt_disk_hits
            + stats.ckpt_remote_hits) > 0
    assert stats.dedup_ratio == pytest.approx(store.dedup_ratio)


def test_snapshot_restore_identity_with_tiered_store(tmp_path):
    """Kill/restore over a *tiered* store: the restored session reuses
    blobs wherever they live (local or demoted to remote) and replays the
    identical logical run — stats equal modulo physical-store counters."""
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(), n_workers=4,
                       store=_tiered(tmp_path))
    svc.submit(SPEC, GridTuner(_space().trials(120)))
    svc.run_until(90.0)
    path = str(tmp_path / "session.pkl")
    svc.snapshot(path)
    reference = svc.close()

    svc2 = StudyService.restore(SearchPlanDB(), path, SimulatedTrainer(),
                                store=_tiered(tmp_path))
    resumed = svc2.close()
    assert det(resumed) == det(reference)
    assert resumed.ckpt_misses == reference.ckpt_misses
