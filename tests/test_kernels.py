"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kernel_ops
from repro.kernels.flash_attention import fa_tile_counts, flash_attention_fwd
from repro.kernels.optim import fused_apply_update
from repro.kernels.ops import (KERNEL_STATS, KernelFallbackWarning,
                               flash_attention, reset_kernel_stats, ssd_intra)
from repro.kernels.ref import attention_ref, ssd_intra_ref
from repro.models.ssm import ssd_chunked, ssd_sequential
from repro.train.optimizer import apply_update, init_opt_state

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Hq,Hkv,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 128, 8, 2, 64),      # GQA 4:1
    (1, 256, 8, 1, 32),      # MQA
    (1, 96, 4, 2, 64),       # ragged (pads to block)
    (2, 64, 2, 1, 128),      # large head dim
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention_matches_ref(B, Sq, Hq, Hkv, hd, causal, window,
                                     dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sq, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sq, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))

    g1 = jax.grad(lambda q_: flash_attention(q_, k, v).sum())(q)
    g2 = jax.grad(lambda q_: attention_ref(q_, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (1, 2, 16, 2, 16, 16),
    (2, 3, 32, 4, 16, 24),
    (1, 1, 64, 1, 32, 32),
    (1, 4, 8, 8, 8, 8),
])
def test_ssd_intra_matches_ref(B, nc, Q, H, P, N, dtype):
    ks = jax.random.split(KEY, 5)
    xr = jax.random.normal(ks[0], (B, nc, Q, H, P), dtype)
    dtr = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
    ltT = -jnp.abs(jax.random.normal(ks[2], (B, nc, H, Q))) * 0.1
    Br = jax.random.normal(ks[3], (B, nc, Q, N), dtype)
    Cr = jax.random.normal(ks[4], (B, nc, Q, N), dtype)
    out = ssd_intra(xr, dtr, ltT, Br, Cr)
    ref = ssd_intra_ref(xr, dtr, ltT, Br, Cr)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_ssd_chunked_matches_sequential(chunk):
    """The chunked SSD algorithm == step-by-step recurrence, any chunking."""
    B, S, H, P, N = 2, 64, 3, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, s2 = ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_chunked_kernel_path_matches_jnp_path():
    B, S, H, P, N = 1, 64, 2, 16, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y1, _ = ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=False)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ---------------------------------------------------------------------------
# backward kernels: jax.grad through the custom_vjp stays on the kernel plane
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Sq,Hq,Hkv,hd,causal,window", [
    (1, 128, 4, 4, 64, True, 0),       # MHA causal
    (2, 128, 8, 2, 64, True, 48),      # GQA 4:1 + sliding window
    (1, 96, 4, 2, 64, False, 0),       # ragged, non-causal
])
def test_flash_attention_bwd_matches_ref(B, Sq, Hq, Hkv, hd, causal, window):
    """dq/dk/dv from the FA2 recompute-tile backward kernels == oracle."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, hd))
    k = jax.random.normal(ks[1], (B, Sq, Hkv, hd))
    v = jax.random.normal(ks[2], (B, Sq, Hkv, hd))

    def loss(fn):
        return lambda q_, k_, v_: fn(
            q_, k_, v_, causal=causal, window=window).sum()

    gk = jax.grad(loss(flash_attention), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss(attention_ref), argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3, err_msg=name)


def test_ssd_intra_grads_match_ref():
    """All five cotangents of the SSD backward kernel == oracle."""
    B, nc, Q, H, P, N = 2, 3, 32, 4, 16, 24
    ks = jax.random.split(KEY, 5)
    xr = jax.random.normal(ks[0], (B, nc, Q, H, P))
    dtr = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
    ltT = -jnp.abs(jax.random.normal(ks[2], (B, nc, H, Q))) * 0.1
    Br = jax.random.normal(ks[3], (B, nc, Q, N))
    Cr = jax.random.normal(ks[4], (B, nc, Q, N))

    gk = jax.grad(lambda *a: ssd_intra(*a).sum(),
                  argnums=(0, 1, 2, 3, 4))(xr, dtr, ltT, Br, Cr)
    gr = jax.grad(lambda *a: ssd_intra_ref(*a).sum(),
                  argnums=(0, 1, 2, 3, 4))(xr, dtr, ltT, Br, Cr)
    for a, b, name in zip(gk, gr, ("dx", "ddt", "dlt", "dB", "dC")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-3, err_msg=name)


def test_flash_attention_grad_under_jit():
    """The kernel-plane vjp composes with jit (the chunk executable path)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    gk = jax.jit(jax.grad(lambda *a: flash_attention(*a).sum(),
                          argnums=(0, 1, 2)))(q, k, v)
    gr = jax.grad(lambda *a: attention_ref(*a).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# pl.when tile skipping: masked KV tiles never execute
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal,window,expect_skips", [
    (True, 0, True),       # upper-triangular tiles skipped
    (True, 64, True),      # window kills tiles below the band too
    (False, 0, False),     # dense: every tile live
])
def test_flash_attention_tile_skipping(causal, window, expect_skips):
    """The executed-tile counter matches the analytic predicate oracle
    (fa_tile_counts) exactly, and the masked tiles really are skipped."""
    B, S, Hq, Hkv, hd, blk = 2, 256, 4, 2, 32, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    out, tiles = flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=blk, block_k=blk,
        count_tiles=True)
    executed, skipped = fa_tile_counts(S, S, blk, blk, causal, window)
    assert int(tiles) == B * Hq * executed
    assert (skipped > 0) == expect_skips
    # skipping must not change the math
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# trial-stacked batching: vmap folds onto the kernel grid == stacked oracle
# ---------------------------------------------------------------------------


def test_vmapped_flash_attention_matches_stacked_oracle():
    M, B, S, Hq, Hkv, hd = 3, 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (M, B, S, Hq, hd))
    k = jax.random.normal(ks[1], (M, B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (M, B, S, Hkv, hd))
    out = jax.vmap(lambda *a: flash_attention(*a, causal=True))(q, k, v)
    ref = jnp.stack([attention_ref(q[i], k[i], v[i], causal=True)
                     for i in range(M)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_vmapped_flash_attention_grad_matches_stacked_oracle():
    """vmap(grad(...)) — the batched-sibling training path — == per-member
    oracle grads, including a broadcast (unbatched) kv operand."""
    M, B, S, Hq, Hkv, hd = 3, 1, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (M, B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    g = jax.vmap(jax.grad(lambda q_, k_, v_: flash_attention(q_, k_, v_).sum(),
                          argnums=(0, 1, 2)), in_axes=(0, None, None))(q, k, v)
    for i in range(M):
        gr = jax.grad(lambda q_, k_, v_: attention_ref(q_, k_, v_).sum(),
                      argnums=(0, 1, 2))(q[i], k, v)
        for a, b in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a[i]), np.asarray(b),
                                       atol=2e-4, rtol=2e-3)


def test_vmapped_ssd_intra_matches_stacked_oracle():
    M, B, nc, Q, H, P, N = 3, 1, 2, 32, 2, 16, 16
    ks = jax.random.split(KEY, 5)
    xr = jax.random.normal(ks[0], (M, B, nc, Q, H, P))
    dtr = jax.nn.softplus(jax.random.normal(ks[1], (M, B, nc, Q, H)))
    ltT = -jnp.abs(jax.random.normal(ks[2], (M, B, nc, H, Q))) * 0.1
    Br = jax.random.normal(ks[3], (M, B, nc, Q, N))
    Cr = jax.random.normal(ks[4], (M, B, nc, Q, N))
    out = jax.vmap(ssd_intra)(xr, dtr, ltT, Br, Cr)
    ref = jnp.stack([ssd_intra_ref(xr[i], dtr[i], ltT[i], Br[i], Cr[i])
                     for i in range(M)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# fused trial-stacked optimizer kernel == apply_update
# ---------------------------------------------------------------------------

OPT_HPS = {
    "sgd": {"lr": 0.1, "wd": 1e-4},
    "momentum": {"lr": 0.1, "wd": 1e-4, "momentum": 0.85},
    "adam": {"lr": 1e-3, "wd": 1e-4, "b1": 0.9, "b2": 0.999, "eps": 1e-8},
    "adamw": {"lr": 1e-3, "wd": 1e-2, "b1": 0.9, "b2": 0.999, "eps": 1e-8},
}


def _opt_problem(name, key, stack=None):
    """Params/grads/state with awkward leaf shapes (exercise lane padding)."""
    shapes = {"w": (37, 5), "b": (7,), "s": (1,)}
    lead = () if stack is None else (stack,)
    ks = jax.random.split(key, 2 * len(shapes))
    params = {k: jax.random.normal(ks[i], lead + s)
              for i, (k, s) in enumerate(shapes.items())}
    grads = {k: jax.random.normal(ks[len(shapes) + i], lead + s) * 0.1
             for i, (k, s) in enumerate(shapes.items())}
    state = {sk: {k: jnp.ones(lead + s) * 0.01 for k, s in shapes.items()}
             for sk in init_opt_state(name, {k: jnp.zeros(s) for k, s
                                             in shapes.items()})}
    return params, grads, state


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_fused_optimizer_matches_apply_update(name):
    params, grads, state = _opt_problem(name, KEY)
    hp = {k: jnp.float32(v) for k, v in OPT_HPS[name].items()}
    step = jnp.int32(3)       # non-trivial adam bias correction
    new_p, new_s = fused_apply_update(name, params, grads, state, hp, step)
    ref_p, ref_s = apply_update(name, params, grads, state, hp, step)
    for a, b in zip(jax.tree.leaves((new_p, new_s)),
                    jax.tree.leaves((ref_p, ref_s))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=1e-6)


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_vmapped_fused_optimizer_divergent_hps(name):
    """vmap over members with per-member hp vectors — the batched-sibling
    optimizer path — == apply_update run member by member."""
    M = 3
    params, grads, state = _opt_problem(name, KEY, stack=M)
    hp = {k: jnp.float32(v) * (1.0 + 0.1 * jnp.arange(M))
          for k, v in OPT_HPS[name].items()}
    step = jnp.arange(M, dtype=jnp.int32)
    new_p, new_s = jax.jit(jax.vmap(
        lambda p, g, s, h, t: fused_apply_update(name, p, g, s, h, t)))(
            params, grads, state, hp, step)
    for i in range(M):
        pi = jax.tree.map(lambda x: x[i], params)
        gi = jax.tree.map(lambda x: x[i], grads)
        si = jax.tree.map(lambda x: x[i], state)
        hi = {k: v[i] for k, v in hp.items()}
        ref_p, ref_s = apply_update(name, pi, gi, si, hi, step[i])
        for a, b in zip(jax.tree.leaves((new_p, new_s)),
                        jax.tree.leaves((ref_p, ref_s))):
            np.testing.assert_allclose(np.asarray(a[i]), np.asarray(b),
                                       atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# fallbacks: counted, reason-tagged, warned exactly once — never silent
# ---------------------------------------------------------------------------


def test_fallback_counted_and_warned_once(monkeypatch):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    ref = attention_ref(q, k, v, causal=True)

    reset_kernel_stats()
    try:
        monkeypatch.setattr(kernel_ops.jax, "default_backend", lambda: "gpu")
        with pytest.warns(KernelFallbackWarning, match="flash_attention"):
            out = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)
        # second call: counted again, but NOT warned again
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            flash_attention(q, k, v, causal=True)
        assert KERNEL_STATS.fallbacks == 2
        assert KERNEL_STATS.calls == 0
        assert KERNEL_STATS.reasons["flash_attention:backend:gpu"] == 2

        # the optimizer gate shares the accounting
        params, grads, state = _opt_problem("sgd", KEY)
        hp = {"lr": jnp.float32(0.1), "wd": jnp.float32(0.0)}
        with pytest.warns(KernelFallbackWarning, match="opt_update"):
            fused_apply_update("sgd", params, grads, state, hp, jnp.int32(0))
        assert KERNEL_STATS.fallbacks == 3
    finally:
        reset_kernel_stats()
