"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention, ssd_intra
from repro.kernels.ref import attention_ref, ssd_intra_ref
from repro.models.ssm import ssd_chunked, ssd_sequential

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Hq,Hkv,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 128, 8, 2, 64),      # GQA 4:1
    (1, 256, 8, 1, 32),      # MQA
    (1, 96, 4, 2, 64),       # ragged (pads to block)
    (2, 64, 2, 1, 128),      # large head dim
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention_matches_ref(B, Sq, Hq, Hkv, hd, causal, window,
                                     dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sq, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sq, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))

    g1 = jax.grad(lambda q_: flash_attention(q_, k, v).sum())(q)
    g2 = jax.grad(lambda q_: attention_ref(q_, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (1, 2, 16, 2, 16, 16),
    (2, 3, 32, 4, 16, 24),
    (1, 1, 64, 1, 32, 32),
    (1, 4, 8, 8, 8, 8),
])
def test_ssd_intra_matches_ref(B, nc, Q, H, P, N, dtype):
    ks = jax.random.split(KEY, 5)
    xr = jax.random.normal(ks[0], (B, nc, Q, H, P), dtype)
    dtr = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
    ltT = -jnp.abs(jax.random.normal(ks[2], (B, nc, H, Q))) * 0.1
    Br = jax.random.normal(ks[3], (B, nc, Q, N), dtype)
    Cr = jax.random.normal(ks[4], (B, nc, Q, N), dtype)
    out = ssd_intra(xr, dtr, ltT, Br, Cr)
    ref = ssd_intra_ref(xr, dtr, ltT, Br, Cr)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_ssd_chunked_matches_sequential(chunk):
    """The chunked SSD algorithm == step-by-step recurrence, any chunking."""
    B, S, H, P, N = 2, 64, 3, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, s2 = ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_chunked_kernel_path_matches_jnp_path():
    B, S, H, P, N = 1, 64, 2, 16, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y1, _ = ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=False)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
