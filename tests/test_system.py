"""End-to-end behaviour: the full Hippo pipeline on real JAX training.

A miniature version of the paper's single-study experiment: a grid study
over lr schedules of a CIFAR-shaped ResNet, executed (a) trial-based and
(b) stage-based on the same engine, asserting the stage run consumes
strictly fewer GPU-seconds while reporting identical-quality metrics; and
the multi-study path sharing across two studies.
"""

import numpy as np
import pytest

from repro.core import (Constant, MultiStep, SearchPlanDB, Study, HpConfig,
                        merge_rate, run_studies)
from repro.core.tuners import GridSearchSpace, GridTuner, SHATuner
from repro.data import DataPipeline, synthetic_cifar
from repro.models.resnet import ResNet
from repro.train.jax_trainer import JaxTrainer


@pytest.fixture(scope="module")
def backend():
    data = synthetic_cifar(256, seed=0)
    eval_data = synthetic_cifar(128, seed=1)
    # backend="cpu" pins the bit-exact unrolled chunk body regardless of
    # the host's accelerators (determinism assertions below rely on it)
    return JaxTrainer(ResNet(n=1, width=8),
                      lambda: DataPipeline(data, batch_size=32, seed=3),
                      eval_data, default_optimizer="momentum", backend="cpu")


def small_space():
    return GridSearchSpace(fns={
        "lr": [Constant(0.05),
               MultiStep(0.05, [10], values=[0.05, 0.005]),
               MultiStep(0.05, [10], values=[0.05, 0.02]),
               MultiStep(0.05, [16], values=[0.05, 0.005])],
        "bs": [Constant(32)]})


def test_single_study_stage_vs_trial(backend):
    trials = small_space().trials(24)
    p = merge_rate(trials)
    assert p > 1.5                                  # the space does share

    db1 = SearchPlanDB()
    st1 = Study.create(db1, "resnet8", "synth", ("lr", "bs"))
    stage = st1.run(GridTuner(small_space().trials(24)), backend, n_workers=2)

    db2 = SearchPlanDB()
    st2 = Study.create(db2, "resnet8", "synth", ("lr", "bs"))
    trial = st2.run(GridTuner(small_space().trials(24)), backend,
                    n_workers=2, share=False)

    assert stage.steps_run < trial.steps_run
    assert trial.steps_run == 4 * 24
    # unique steps: shared prefix [0,16) + per-trial tails
    assert stage.steps_run == (24 + 14 + 14 + 8)


def test_multi_study_shares_across_studies(backend):
    db = SearchPlanDB()
    s1 = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    s2 = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    stats = run_studies(
        [(s1, GridTuner(small_space().trials(24))),
         (s2, GridTuner(small_space().trials(24)))],
        backend, n_workers=2)
    # study 2 is identical to study 1 → costs nothing extra in steps
    assert stats.steps_run == (24 + 14 + 14 + 8)


def test_sha_on_real_training(backend):
    db = SearchPlanDB()
    st = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    tuner = SHATuner(small_space().trials(24), min_steps=6, max_steps=24,
                     eta=2)
    stats = st.run(tuner, backend, n_workers=2)
    assert tuner.is_done()
    assert tuner.best is not None
    assert np.isfinite(tuner.best_score)
