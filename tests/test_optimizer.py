"""Optimizers: updates match hand-derived math; hp values are dynamic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import apply_update, init_opt_state


def p0():
    return {"w": jnp.array([1.0, -2.0]), "b": jnp.array([0.5])}


def g0():
    return {"w": jnp.array([0.1, 0.2]), "b": jnp.array([-0.3])}


def test_sgd():
    params, grads = p0(), g0()
    new, _ = apply_update("sgd", params, grads, {}, {"lr": 0.1}, jnp.int32(0))
    np.testing.assert_allclose(new["w"], [1.0 - 0.01, -2.0 - 0.02])


def test_sgd_weight_decay():
    params, grads = p0(), g0()
    new, _ = apply_update("sgd", params, grads, {},
                          {"lr": 0.1, "wd": 0.01}, jnp.int32(0))
    np.testing.assert_allclose(new["w"][0], 1.0 - 0.1 * (0.1 + 0.01 * 1.0))


def test_momentum_two_steps():
    params, grads = p0(), g0()
    st = init_opt_state("momentum", params)
    p1, st = apply_update("momentum", params, grads, st,
                          {"lr": 0.1, "momentum": 0.9}, jnp.int32(0))
    p2, st = apply_update("momentum", p1, grads, st,
                          {"lr": 0.1, "momentum": 0.9}, jnp.int32(1))
    # v1 = g; v2 = 0.9 g + g = 1.9 g
    np.testing.assert_allclose(
        p2["w"], p0()["w"] - 0.1 * g0()["w"] - 0.1 * 1.9 * g0()["w"],
        rtol=1e-6)


def test_adam_bias_correction_first_step():
    params, grads = p0(), g0()
    st = init_opt_state("adam", params)
    new, st = apply_update("adam", params, grads, st,
                           {"lr": 0.001}, jnp.int32(0))
    # after bias correction, first step ≈ -lr * sign-ish(g)
    expect = p0()["w"] - 0.001 * g0()["w"] / (jnp.abs(g0()["w"]) + 1e-8)
    np.testing.assert_allclose(new["w"], expect, rtol=1e-4)


def test_adamw_decouples_wd():
    params, grads = p0(), g0()
    st = init_opt_state("adamw", params)
    a, _ = apply_update("adamw", params, grads, st,
                        {"lr": 0.001, "wd": 0.0}, jnp.int32(0))
    b, _ = apply_update("adamw", params, grads, init_opt_state("adamw", params),
                        {"lr": 0.001, "wd": 0.1}, jnp.int32(0))
    diff = np.asarray(a["w"] - b["w"])
    np.testing.assert_allclose(diff, 0.001 * 0.1 * np.asarray(p0()["w"]),
                               rtol=1e-3)  # f32 arithmetic


def test_lr_is_dynamic_no_retrace():
    """One compiled step serves every lr value (the Hippo requirement)."""
    traces = 0

    def step(params, grads, st, hp):
        nonlocal traces
        traces += 1
        return apply_update("sgd", params, grads, st, hp, jnp.int32(0))

    jstep = jax.jit(step)
    params, grads = p0(), g0()
    for lr in (0.1, 0.01, 0.001, 0.37):
        jstep(params, grads, {}, {"lr": jnp.float32(lr)})
    assert traces == 1


def test_unknown_optimizer_raises():
    with pytest.raises(ValueError):
        init_opt_state("lion", p0())
