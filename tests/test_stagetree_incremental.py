"""Incremental stage-tree builder ≡ from-scratch Algorithm 1.

Property-style equivalence: for randomized interleavings of submit /
record_result / mark_running / kill operations, the revision-memoized
:class:`StageTreeBuilder` must produce stage trees *identical* to
``build_stage_tree`` — same stage ids in the same order, same intervals,
resumes, parents and report flags — and the maintained pending-request
index must agree with a full scan.
"""

import random

from repro.core.hpseq import Constant, HpConfig, MultiStep
from repro.core.searchplan import Request, SearchPlan
from repro.core.stagetree import (StageTreeBuilder, build_stage_tree,
                                  stage_trees_equal)
from repro.core.trial import Trial


def random_trial(rng: random.Random) -> Trial:
    """Trials over a small space so prefixes merge often."""
    steps = rng.choice([40, 80, 120, 160])
    base = rng.choice([0.1, 0.2])
    n_drops = rng.randint(0, 2)
    bounds = sorted(rng.sample([20, 40, 60, 80, 100, 120], n_drops))
    bounds = [b for b in bounds if b < steps]
    values = [base] + [round(base * 0.5 ** (i + 1), 4)
                       for i in range(len(bounds))]
    lr = MultiStep(base, bounds, values=values) if bounds else Constant(base)
    return Trial(HpConfig({"lr": lr}), steps)


def check(plan: SearchPlan, builder: StageTreeBuilder) -> None:
    assert plan.pending_requests() == plan.pending_requests_scan()
    incremental = builder.build()
    scratch = build_stage_tree(plan)
    assert stage_trees_equal(incremental, scratch), (
        f"diverged at revision {plan.revision}:\n"
        f"  incremental: {sorted(map(repr, incremental.stages.values()))}\n"
        f"  scratch:     {sorted(map(repr, scratch.stages.values()))}")


def random_walk(seed: int, n_ops: int = 120) -> None:
    rng = random.Random(seed)
    plan = SearchPlan(f"prop-{seed}")
    builder = StageTreeBuilder(plan)
    live_trials = []
    running = []

    for _ in range(n_ops):
        op = rng.random()
        if op < 0.40 or not plan.nodes:
            t = random_trial(rng)
            plan.submit(t, upto=rng.choice([None, 20, 60, 100]))
            live_trials.append(t)
        elif op < 0.65:
            pend = plan.pending_requests()
            if pend:
                req = rng.choice(pend)
                plan.mark_running([req])
                running.append(req)
        elif op < 0.90:
            if running:
                req = running.pop(rng.randrange(len(running)))
                with_metrics = rng.random() < 0.8
                plan.record_result(
                    req.node_id, req.step, f"ck-{req.node_id}-{req.step}",
                    {"val_acc": rng.random()} if with_metrics else None)
            elif plan.pending_requests():
                # checkpoint landing without an explicit running mark
                req = rng.choice(plan.pending_requests())
                plan.record_result(req.node_id, req.step,
                                   f"ck-{req.node_id}-{req.step}",
                                   {"val_acc": rng.random()})
        else:
            if live_trials:
                t = live_trials.pop(rng.randrange(len(live_trials)))
                path = list(plan.trial_paths.get(t.trial_id, []))
                dead = plan.release_trial(t.trial_id)
                for nid in path:
                    node = plan.nodes[nid]
                    for s in sorted(node.requests):
                        if s not in node.running and s not in node.metrics:
                            plan.drop_request(nid, s)
                for nid in dead:
                    plan.evict_ckpts(nid)
        check(plan, builder)


def test_randomized_equivalence():
    for seed in range(8):
        random_walk(seed)


def test_builder_tree_cache_on_unchanged_revision():
    plan = SearchPlan()
    plan.submit(Trial(HpConfig({"lr": Constant(0.1)}), 100))
    builder = StageTreeBuilder(plan)
    t1 = builder.build()
    t2 = builder.build()
    assert t1 is t2                      # same revision → same tree object
    assert builder.tree_cache_hits == 1
    plan.submit(Trial(HpConfig({"lr": Constant(0.2)}), 100))
    t3 = builder.build()
    assert t3 is not t2
    assert stage_trees_equal(t3, build_stage_tree(plan))


def test_memoized_resolutions_are_reused():
    """Steady-state round: resolving a new request must not re-resolve the
    untouched rest of the plan."""
    plan = SearchPlan()
    for v in (0.1, 0.2, 0.3, 0.4):
        plan.submit(Trial(HpConfig({"lr": Constant(v)}), 100))
    builder = StageTreeBuilder(plan)
    builder.build()
    first_resolves = builder.resolves
    assert first_resolves >= 4
    # satisfy one request; only that node's subtree should re-resolve
    req = plan.pending_requests()[0]
    plan.record_result(req.node_id, req.step, "ck", {"val_acc": 0.5})
    builder.build()
    assert builder.resolves - first_resolves == 0      # nothing new to resolve
    assert builder.resolve_hits >= 3                   # survivors were cached


def test_stale_defer_is_invalidated_when_running_clears():
    """A deferred resolution must be recomputed once the running stage
    deposits its checkpoint — including the intermediate parent request."""
    plan = SearchPlan()
    long = Trial(HpConfig(
        {"lr": MultiStep(0.1, [50], values=[0.1, 0.05])}), 100)
    leaf, _, _ = plan.submit(long)
    root = plan.path_to_root(leaf.node_id)[0]
    builder = StageTreeBuilder(plan, verify=True)
    builder.build()
    # root starts running → child request defers
    plan.mark_running([Request(root.node_id, 50)])
    assert len(builder.build()) == 0
    # root finishes with a checkpoint at 50 → child resumes from it
    plan.record_result(root.node_id, 50, "ck50", {"val_acc": 0.4})
    tree = builder.build()
    stages = sorted(tree.stages.values(), key=lambda s: s.start)
    assert stages[0].resume == (root.node_id, 50) or (
        stages[0].node_id == leaf.node_id)
    assert stage_trees_equal(tree, build_stage_tree(plan))


def test_eviction_invalidates_resume_points():
    plan = SearchPlan()
    t = Trial(HpConfig({"lr": Constant(0.1)}), 200)
    node, _, _ = plan.submit(t)
    plan.record_result(node.node_id, 120, "ck120", None)
    builder = StageTreeBuilder(plan, verify=True)
    (st,) = builder.build().stages.values()
    assert st.resume == (node.node_id, 120)
    plan.evict_ckpts(node.node_id)
    (st2,) = builder.build().stages.values()
    assert st2.resume is None and st2.start == 0       # fresh retrain
