"""Chain-fused execution semantics: device-resident carries across stage
boundaries + the write-behind checkpoint plane.

A chain-capable simulated backend (virtual durations, dict states) drives
the engine-level contracts cheaply:

* chain fusion is *accounting-invariant*: the same study produces exactly
  the same virtual clock, GPU-seconds, metrics and checkpoints as the
  per-stage loop (events still land per stage);
* kill-mid-chain lands the completed prefix (flushed, GC-correct,
  resumable) and discards the in-flight suffix — including cancelling
  write-behind commits that have not hit disk yet;
* engine shutdown is a ``flush()`` barrier: every checkpoint the plan
  records is durably on disk when ``run()`` returns;
* ``sibling_chain_groups`` extends sibling groups down parallel chains
  with identical per-stage signatures and stops at forks / divergences.
"""

import os

import numpy as np
import pytest

from repro.core import (Constant, HpConfig, MultiStep, SearchPlanDB, Study)
from repro.core.engine import Tuner
from repro.core.searchplan import SearchPlan
from repro.core.stagetree import build_stage_tree, sibling_chain_groups
from repro.core.trainer import SimulatedTrainer
from repro.core.trial import Trial
from repro.core.tuners import GridTuner, SHATuner
from repro.train.checkpoint import CheckpointStore


class ChainSimTrainer(SimulatedTrainer):
    """Simulated backend that advertises chain fusion: the default
    ``run_chain`` per-stage loop already returns boundary states, so the
    flag alone routes execution through the dispatcher's fused path."""

    supports_chain_fusion = True


class BatchedChainSimTrainer(ChainSimTrainer):
    supports_batched_stages = True


def seq_trial(lr0, lr1, steps=24, boundary=12, bs=None):
    hps = {"lr": MultiStep(lr0, [boundary], values=[lr0, lr1])}
    if bs is not None:
        hps["bs"] = Constant(bs)
    return Trial(HpConfig(hps), steps)


def stats_key(stats):
    return (round(stats.gpu_seconds, 9), round(stats.end_to_end, 9),
            stats.stages_run, stats.steps_run, stats.evals_run,
            stats.ckpt_saves, stats.ckpt_loads)


def run_sha(backend, chain_fusion, store=None, n_workers=2,
            worker_meshes=None):
    db = SearchPlanDB()
    study = Study.create(db, "m", "d", ("lr",))
    trials = [seq_trial(0.1 - 0.01 * i, 0.01 - 0.001 * i, steps=24)
              for i in range(6)]
    tuner = SHATuner(trials, min_steps=12, max_steps=24, eta=2)
    eng = study.engine(backend, n_workers=n_workers, store=store,
                       chain_fusion=chain_fusion,
                       worker_meshes=worker_meshes)
    stats = eng.run([tuner])
    return db.get(study.key), eng, stats


# ---------------------------------------------------------------------------
# accounting invariance
# ---------------------------------------------------------------------------


def test_chain_fusion_is_accounting_invariant():
    """Fused chains post the same per-stage events at the same virtual
    times as the per-stage loop: every stat and every recorded metric is
    identical, only the chain_fused_stages counter moves."""
    plan_f, eng_f, stats_f = run_sha(ChainSimTrainer(), chain_fusion=True)
    plan_u, eng_u, stats_u = run_sha(ChainSimTrainer(), chain_fusion=False)

    assert stats_f.chain_fused_stages > 0
    assert stats_u.chain_fused_stages == 0
    assert stats_f.ckpt_async_writes == stats_f.ckpt_saves
    assert stats_u.ckpt_async_writes == 0
    assert stats_key(stats_f) == stats_key(stats_u)

    assert set(plan_f.nodes) == set(plan_u.nodes)
    for nid, node in plan_f.nodes.items():
        assert node.metrics == plan_u.nodes[nid].metrics
        assert set(node.ckpts) == set(plan_u.nodes[nid].ckpts)


def test_one_device_mesh_fleet_is_accounting_invariant():
    """Distribution plane v2: width-1 worker meshes are pure bookkeeping —
    the chain-fused batched run replays the thread fleet's virtual clock,
    checkpoints and metrics exactly; only the mesh-plane counters move."""
    from repro.dist.meshes import plan_worker_meshes

    plan_m, eng_m, stats_m = run_sha(
        BatchedChainSimTrainer(), chain_fusion=True,
        worker_meshes=plan_worker_meshes(2, 1))
    plan_t, eng_t, stats_t = run_sha(BatchedChainSimTrainer(),
                                     chain_fusion=True)
    assert stats_m.mesh_placements > 0
    assert stats_t.mesh_placements == 0
    assert stats_key(stats_m) == stats_key(stats_t)
    assert set(plan_m.nodes) == set(plan_t.nodes)
    for nid, node in plan_m.nodes.items():
        assert node.metrics == plan_t.nodes[nid].metrics
        assert set(node.ckpts) == set(plan_t.nodes[nid].ckpts)


def test_simulated_backend_defaults_to_unfused():
    # SimulatedTrainer does not advertise chain fusion: the knob cannot
    # force the fused path onto a backend without support
    db = SearchPlanDB()
    study = Study.create(db, "m", "d", ("lr",))
    eng = study.engine(SimulatedTrainer(), chain_fusion=True)
    assert eng.chain_fusion is False


# ---------------------------------------------------------------------------
# write-behind: shutdown barrier + kill-mid-chain
# ---------------------------------------------------------------------------


def test_engine_shutdown_flushes_write_behind(tmp_path):
    store = CheckpointStore(str(tmp_path))
    plan, eng, stats = run_sha(ChainSimTrainer(), chain_fusion=True,
                               store=store)
    assert stats.ckpt_async_writes > 0
    assert store.pending_writes == 0           # flush barrier ran
    for node in plan.nodes.values():           # every recorded cid is durable
        for cid in node.ckpts.values():
            assert os.path.exists(store._path(cid)), cid


def test_kill_mid_chain_lands_prefix_discards_suffix(tmp_path):
    """SHA kills losers whose later-stage results are still in flight: the
    shared/completed prefix stays resumable on disk, the dead suffix is
    evicted — even when its write-behind commit had not landed."""
    store = CheckpointStore(str(tmp_path))
    plan, eng, stats = run_sha(ChainSimTrainer(), chain_fusion=True,
                               store=store, n_workers=1)
    assert stats.chain_fused_stages > 0
    assert stats.ckpt_evictions > 0            # losers reclaimed
    assert store.pending_writes == 0
    for node in plan.nodes.values():
        if node.refcount <= 0:                 # dead: no checkpoints anywhere
            assert node.ckpts == {}
        for cid in node.ckpts.values():
            assert os.path.exists(store._path(cid))
    # the store holds exactly the surviving checkpoints (cancelled pending
    # writes never materialized files)
    live = {cid for node in plan.nodes.values()
            for cid in node.ckpts.values()}
    on_disk = {f for f in os.listdir(str(tmp_path)) if f.endswith(".ckpt")}
    assert on_disk == {os.path.basename(store._path(c)) for c in live}


class KillAfterFirstReport(Tuner):
    """Submits two requests per trial (mid-chain report at ``rung``), then
    kills the weaker trial at the rung — exercising a kill whose chain had
    already run to completion in one fused dispatch."""

    def __init__(self, trials, rung):
        self.trials = trials
        self.rung = rung
        self.scores = {}
        self.done_trials = set()

    def start(self, handle):
        self.handle = handle
        for t in self.trials:
            handle.submit(t, upto=self.rung)
            handle.submit(t)                   # full budget, same chain

    def on_result(self, trial, step, metrics):
        if step == self.rung:
            self.scores[trial.trial_id] = self.score(metrics)
            if len(self.scores) == len(self.trials):
                worst = min(self.scores, key=self.scores.get)
                for t in self.trials:
                    if t.trial_id == worst:
                        self.handle.kill(t)
                        self.done_trials.add(t.trial_id)
        else:
            self.done_trials.add(trial.trial_id)

    def is_done(self):
        return len(self.done_trials) >= len(self.trials)


def test_kill_races_running_fused_chain(tmp_path):
    store = CheckpointStore(str(tmp_path))
    db = SearchPlanDB()
    study = Study.create(db, "m", "d", ("lr",))
    trials = [seq_trial(0.1, 0.01), seq_trial(0.09, 0.009)]
    tuner = KillAfterFirstReport(trials, rung=12)
    eng = study.engine(ChainSimTrainer(), n_workers=1, store=store)
    stats = eng.run([tuner])
    assert eng.chain_fusion
    assert stats.chain_fused_stages >= 4       # two depth->=2 fused chains
    plan = db.get(study.key)
    # the killed trial's exclusive suffix node is gone, its files too
    dead = [n for n in plan.nodes.values() if n.refcount <= 0]
    assert dead and all(n.ckpts == {} for n in dead)
    assert store.pending_writes == 0
    for node in plan.nodes.values():
        for cid in node.ckpts.values():
            assert os.path.exists(store._path(cid))


# ---------------------------------------------------------------------------
# sibling-chain groups
# ---------------------------------------------------------------------------


def test_sibling_chain_groups_extend_down_parallel_chains():
    plan = SearchPlan("g")
    for i, lr in enumerate((0.1, 0.05, 0.025)):
        plan.submit(Trial(HpConfig(
            {"lr": MultiStep(lr, [10], values=[lr, lr / 10]),
             "bs": Constant(32)}), 20, trial_id=f"t{i}"))
    tree = build_stage_tree(plan)
    groups = sibling_chain_groups(plan, tree)
    assert len(groups) == 1
    chains = groups[0]
    assert len(chains) == 3                    # three parallel trials
    assert all(len(c) == 2 for c in chains)    # extended over the boundary
    for c in chains:
        assert (c[0].start, c[0].stop) == (0, 10)
        assert (c[1].start, c[1].stop) == (10, 20)
        assert c[1].parent == c[0].stage_id


def test_sibling_chain_groups_stop_at_bs_divergence():
    plan = SearchPlan("g2")
    # divergent head values (parallel chains); the second level diverges
    # in batch-size schedule, which must stop the extension
    for i, bs_tail in enumerate((32, 64)):
        lr = 0.1 - 0.01 * i
        plan.submit(Trial(HpConfig(
            {"lr": MultiStep(lr, [10], values=[lr, lr / 10]),
             "bs": MultiStep(32, [10], values=[32, bs_tail])}), 20,
            trial_id=f"t{i}"))
    tree = build_stage_tree(plan)
    groups = sibling_chain_groups(plan, tree)
    assert len(groups) == 1
    assert all(len(c) == 1 for c in groups[0])   # heads only, no extension


def test_batched_chain_group_matches_sequential_engine():
    """Forced batched multi-stage chains on the simulator reproduce the
    sequential engine's metrics and checkpoints exactly."""
    def run(backend, batch, fusion):
        db = SearchPlanDB()
        study = Study.create(db, "m", "d", ("lr",))
        trials = [seq_trial(0.1 - 0.02 * i, 0.01 - 0.002 * i, steps=20,
                            boundary=10) for i in range(3)]
        eng = study.engine(backend, n_workers=1, batch_siblings=batch,
                           chain_fusion=fusion)
        stats = eng.run([GridTuner(trials)])
        return db.get(study.key), stats

    plan_b, stats_b = run(BatchedChainSimTrainer(), batch=True, fusion=True)
    plan_s, stats_s = run(SimulatedTrainer(), batch=False, fusion=False)

    assert stats_b.batched_groups >= 1
    assert stats_b.batched_stages >= 4         # >=2 members x depth 2
    assert stats_b.chain_fused_stages >= 4
    assert set(plan_b.nodes) == set(plan_s.nodes)
    for nid, node in plan_b.nodes.items():
        assert node.metrics == plan_s.nodes[nid].metrics


def test_chain_groups_respect_max_steps_per_chain():
    """The per-dispatch work cap applies to batched chain groups exactly
    as to scheduler-extracted chains: no single backend call may exceed
    it (the cut levels reschedule in later rounds)."""
    class RecordingBackend(BatchedChainSimTrainer):
        def __init__(self):
            super().__init__()
            self.dispatch_steps = []

        def run_chain(self, state, ctxs):
            self.dispatch_steps.append(sum(c.stop - c.start for c in ctxs))
            return super().run_chain(state, ctxs)

        def run_stages_batched(self, states, ctxs):
            self.dispatch_steps.extend(c.stop - c.start for c in ctxs)
            return super().run_stages_batched(states, ctxs)

        def run_chains_batched(self, states, chains):
            self.dispatch_steps.extend(
                sum(c.stop - c.start for c in ch) for ch in chains)
            return super().run_chains_batched(states, chains)

    backend = RecordingBackend()
    db = SearchPlanDB()
    study = Study.create(db, "m", "d", ("lr",))
    trials = [seq_trial(0.1 - 0.02 * i, 0.01 - 0.002 * i, steps=20,
                        boundary=10) for i in range(3)]
    eng = study.engine(backend, n_workers=1, batch_siblings=True,
                       chain_fusion=True, max_steps_per_chain=10)
    stats = eng.run([GridTuner(trials)])
    assert backend.dispatch_steps and max(backend.dispatch_steps) <= 10
    assert stats.steps_run == 60                   # everything still ran
