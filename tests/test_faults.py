"""Fault plane: deterministic injection, retry/quarantine/degradation
failure domains, and crash-consistent session snapshots.

The load-bearing property throughout: faults change *when* work runs, never
*what* it computes — every faulty run must finish with leaf checkpoints
bitwise-identical to the fault-free run, with the retry waste accounted in
``wasted_gpu_seconds`` and kept out of the sharing studies' fair-share
charges.
"""

import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (FatalStageError, FaultInjector, SearchPlanDB,
                        StudyService, StudySpec, TransientStageError,
                        WorkerCrashed)
from repro.core.engine import (capture_session, load_latest_session,
                               migrate_session, restore_engine, save_session,
                               save_session_rotated, session_rotation)
from repro.core.faults import is_transient, raw_store
from repro.core.hpseq import Constant, Exponential, StepLR, Warmup
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridSearchSpace, GridTuner

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SPEC = StudySpec("m", "d", ("lr", "bs"))


def _space(n_lr: int = 3) -> GridSearchSpace:
    lrs = [StepLR(0.1, 0.1, [30]), StepLR(0.1, 0.1, [40]),
           Warmup(5, 0.1, Exponential(0.1, 0.95))][:n_lr]
    return GridSearchSpace(fns={"lr": lrs,
                                "bs": [Constant(64), Constant(128)]})


def det(stats):
    """Deterministic view (same contract as test_service.det): physical
    wall timers and physical-store counters vary run to run; everything
    else must replay exactly."""
    import dataclasses
    return dataclasses.replace(
        stats, ckpt_save_seconds=0.0, ckpt_load_seconds=0.0,
        ckpt_delta_bytes=0, ckpt_full_bytes=0, ckpt_logical_bytes=0,
        ckpt_bytes_written=0, ckpt_delta_commits=0, ckpt_delta_rebases=0,
        ckpt_mem_hits=0, ckpt_disk_hits=0, ckpt_remote_hits=0,
        ckpt_store_misses=0, ckpt_tier_promotions=0, ckpt_tier_demotions=0,
        ckpt_tmp_reclaimed=0, d2d_handoffs=0)


def run_session(injector=None, *, n_workers=4, steps=80, second_study=True,
                backend=None, **engine_kw):
    """Two-study fair-share session; returns (stats, leaves, service)."""
    db = SearchPlanDB()
    svc = StudyService(db, backend or SimulatedTrainer(horizon=steps),
                       n_workers=n_workers, policy="fair_share",
                       fault_injector=injector, **engine_kw)
    svc.submit(SPEC, GridTuner(_space().trials(steps)))
    if second_study:
        svc.submit(SPEC, GridTuner(_space().trials(steps)[:4]), at=200.0)
    stats = svc.close()
    eng = svc._engine
    store = raw_store(eng.store)
    leaves = {}
    for nid, node in eng.plan.nodes.items():
        for step, cid in node.ckpts.items():
            try:
                leaves[(nid, step)] = store.get(cid)
            except KeyError:
                pass                       # GC'd interior boundary
    return stats, leaves, svc


def assert_leaves_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        assert set(a[k]) == set(b[k])
        for name in a[k]:
            np.testing.assert_array_equal(np.asarray(a[k][name]),
                                          np.asarray(b[k][name]))


# ---------------------------------------------------------------------------
# injector determinism
# ---------------------------------------------------------------------------

def _drain_schedule(inj, n=200):
    out = []
    for i in range(n):
        try:
            inj.before_execute(f"s{i}")
        except Exception as e:
            out.append(type(e).__name__)
    return out, list(inj.log)


def test_same_seed_same_schedule():
    a = FaultInjector(42, stage_fault_rate=0.2, crash_rate=0.1)
    b = FaultInjector(42, stage_fault_rate=0.2, crash_rate=0.1)
    sched_a, log_a = _drain_schedule(a)
    sched_b, log_b = _drain_schedule(b)
    assert sched_a == sched_b and log_a == log_b
    assert a.injected == b.injected > 0


def test_different_seed_different_schedule():
    a = FaultInjector(1, stage_fault_rate=0.2, crash_rate=0.1)
    b = FaultInjector(2, stage_fault_rate=0.2, crash_rate=0.1)
    assert _drain_schedule(a)[0] != _drain_schedule(b)[0]


def test_max_faults_bounds_schedule():
    inj = FaultInjector(0, stage_fault_rate=1.0, max_faults=3)
    fired, _ = _drain_schedule(inj, 50)
    assert len(fired) == 3 and inj.injected == 3


def test_outage_window_counts_once():
    inj = FaultInjector(0, outage_rate=1.0, outage_ops=3)
    from repro.core import StoreOutageError
    for _ in range(3):                    # the fired op + 2 window ops
        with pytest.raises(StoreOutageError):
            inj.on_store_op("get", "cid")
    assert inj.injected == 1 and inj.by_kind == {"outage": 1}


def test_fault_taxonomy():
    assert is_transient(TransientStageError("x"))
    assert is_transient(WorkerCrashed("x"))
    assert not is_transient(FatalStageError("x"))
    assert not is_transient(ValueError("x"))
    # injected faults must NOT alias the dispatcher's fall-back signal
    assert not isinstance(TransientStageError("x"), ValueError)


# ---------------------------------------------------------------------------
# the acceptance run: faults injected, session completes bitwise-equal
# ---------------------------------------------------------------------------

def test_faulty_session_bitwise_equals_fault_free():
    """Seeded schedule of worker crashes + transient stage failures + a
    store outage: the multi-study session completes, retries happened,
    every final leaf is bitwise-equal to the fault-free run, and the
    retry waste never lands in the sharing studies' fair-share charges."""
    ref, leaves_ref, _ = run_session(None)
    inj = FaultInjector(11, stage_fault_rate=0.25, crash_rate=0.15,
                        outage_rate=0.02, outage_ops=2)
    got, leaves_got, _ = run_session(inj)

    assert inj.injected > 0 and got.faults_injected == inj.injected
    assert {"stage", "crash", "outage"} <= set(inj.by_kind)
    assert got.stage_retries > 0
    assert got.stage_failures >= got.stage_retries
    assert got.wasted_gpu_seconds > 0

    assert got.steps_run == ref.steps_run
    assert_leaves_equal(leaves_ref, leaves_got)

    # useful work is conserved: waste is charged to wasted_gpu_seconds
    # only, so the per-study fair-share totals still sum to the fault-free
    # total (the split between studies may shift — faults move stages
    # across the second study's admission time)
    total_ref = sum(s.gpu_seconds for s in ref.by_study.values())
    total_got = sum(s.gpu_seconds for s in got.by_study.values())
    assert total_got == pytest.approx(total_ref)
    # global gpu_seconds may exceed the fault-free run slightly: retries
    # re-load their boundary checkpoint, and load stalls are charged to
    # the global counter (never to a study)
    assert got.gpu_seconds >= total_got


def test_crash_heavy_run_quarantines_and_completes():
    inj = FaultInjector(3, crash_rate=0.45, stage_fault_rate=0.1)
    got, leaves_got, _ = run_session(inj, n_workers=2, second_study=False)
    ref, leaves_ref, _ = run_session(None, n_workers=2, second_study=False)
    assert inj.by_kind.get("crash", 0) > 0
    assert got.workers_quarantined > 0
    assert got.steps_run == ref.steps_run
    assert_leaves_equal(leaves_ref, leaves_got)


def test_straggler_completes_but_slower():
    inj = FaultInjector(5, straggler_rate=1.0, straggler_factor=4.0)
    got, leaves_got, _ = run_session(inj, second_study=False)
    ref, leaves_ref, _ = run_session(None, second_study=False)
    assert inj.by_kind.get("straggler", 0) > 0
    assert got.stage_failures == 0            # performance fault only
    assert got.steps_run == ref.steps_run
    assert got.gpu_seconds > ref.gpu_seconds  # slowdown is real + accounted
    assert_leaves_equal(leaves_ref, leaves_got)


def test_fatal_fault_propagates():
    class FatalOnce(FaultInjector):
        def __init__(self):
            super().__init__(0)
            self._armed = True

        def before_execute(self, site):
            if self._armed:
                self._armed = False
                self._record("fatal", site)
                raise FatalStageError(f"injected fatal at {site}")

    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(horizon=80), n_workers=2,
                       fault_injector=FatalOnce())
    svc.submit(SPEC, GridTuner(_space(1).trials(80)))
    with pytest.raises(FatalStageError):
        svc.close()


def test_retry_budget_is_consecutive_not_cumulative():
    """``max_stage_retries`` bounds consecutive failures of one unit: a
    unit that fails, recovers, and fails again later must not accrue
    attempts across unrelated incidents until a recoverable fault is
    misclassified as exhausted."""

    class EveryOtherAttempt(FaultInjector):
        """Fail every other execution attempt, forever — far more total
        faults per unit than max_stage_retries, never two in a row."""

        def __init__(self):
            super().__init__(0)
            self._flip = False

        def before_execute(self, site):
            self._flip = not self._flip
            if self._flip:
                self._record("stage", site)
                raise TransientStageError(f"injected at {site}")

    inj = EveryOtherAttempt()
    # the session completes — without the consecutive-reset, attempt
    # counts accrue across incidents and this raises TransientStageError
    got, leaves_got, svc = run_session(inj, n_workers=2, second_study=False)
    ref, leaves_ref, _ = run_session(None, n_workers=2, second_study=False)
    disp = svc._engine.dispatcher
    assert got.stage_retries > disp.max_stage_retries
    # this schedule forces recompute-on-miss (a retry whose resume
    # checkpoint was GC'd re-derives from an earlier boundary), so total
    # steps may exceed the fault-free run — but every terminal leaf is
    # still bitwise-identical
    assert got.steps_run >= ref.steps_run
    terminal = {k for k in leaves_ref if k[1] == 80}
    assert terminal and terminal <= set(leaves_got)
    assert_leaves_equal({k: leaves_ref[k] for k in terminal},
                        {k: leaves_got[k] for k in terminal})


def test_retry_exhaustion_propagates():
    inj = FaultInjector(0, stage_fault_rate=1.0)   # every attempt fails
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(horizon=80), n_workers=2,
                       fault_injector=inj)
    svc.submit(SPEC, GridTuner(_space(1).trials(80)))
    with pytest.raises(TransientStageError):
        svc.close()


def test_batched_group_degrades_to_solo():
    """A transient fault inside a batched sibling-group call degrades the
    group to per-member solo execution instead of failing it wholesale."""
    import test_chainfusion as cf

    def run(inj):
        db = SearchPlanDB()
        svc = StudyService(db, cf.BatchedChainSimTrainer(horizon=48),
                           n_workers=1, fault_injector=inj,
                           batch_siblings=True)
        svc.submit(StudySpec("m", "d", ("lr",)),
                   GridTuner([cf.seq_trial(0.1 - 0.01 * i, 0.01, steps=48,
                                           boundary=24) for i in range(4)]))
        stats = svc.close()
        eng = svc._engine
        store = raw_store(eng.store)
        leaves = {(nid, st): store.get(cid)
                  for nid, node in eng.plan.nodes.items()
                  for st, cid in node.ckpts.items() if store.contains(cid)}
        return stats, leaves

    ref, leaves_ref = run(None)
    assert ref.batched_groups > 0, "scenario never batched"

    class GroupFault(FaultInjector):
        """Deterministically fail the first batched-group attempt."""
        def __init__(self):
            super().__init__(0)
            self._armed = True

        def before_execute(self, site):
            if self._armed and site.startswith(("group:", "group-chain:")):
                self._armed = False
                self._record("stage", site)
                raise TransientStageError(f"injected group fault at {site}")

    inj = GroupFault()
    got, leaves_got = run(inj)
    assert inj.injected == 1
    assert got.groups_degraded == 1
    assert got.steps_run == ref.steps_run
    assert_leaves_equal(leaves_ref, leaves_got)


def test_store_outage_only_run_completes():
    inj = FaultInjector(9, outage_rate=0.15, outage_ops=2)
    got, leaves_got, _ = run_session(inj, second_study=False)
    ref, leaves_ref, _ = run_session(None, second_study=False)
    assert inj.by_kind.get("outage", 0) > 0
    assert got.stage_retries > 0
    assert got.steps_run == ref.steps_run
    assert_leaves_equal(leaves_ref, leaves_got)


def test_faulty_jax_run_bitwise_equals_fault_free():
    """test_lossless-style, on the real JaxTrainer: a faulty run's leaf
    states (params, optimizer, data cursor) are bit-identical to the
    fault-free run's — retry from the boundary checkpoint replays the
    exact same computation."""
    from test_dataplane import assert_states_identical, tiny_backend
    from repro.core import Study
    from repro.core.hpseq import HpConfig, MultiStep
    from repro.core.trial import Trial

    def run(inj):
        db = SearchPlanDB()
        study = Study.create(db, "m", "d", ("lr",))
        trials = [Trial(HpConfig({"lr": MultiStep(0.1, [8],
                                                  values=[0.1, v])}), 16)
                  for v in (0.05, 0.02, 0.01)]
        eng = study.engine(tiny_backend(), n_workers=2, fault_injector=inj)
        stats = eng.run([GridTuner(trials)])
        return db.get(study.key), eng, stats, trials

    plan_ref, eng_ref, ref, trials = run(None)
    inj = FaultInjector(2, stage_fault_rate=0.3, crash_rate=0.2)
    plan_got, eng_got, got, _ = run(inj)
    assert inj.injected > 0, "seed drew no faults — pick another"
    assert got.stage_retries > 0
    assert got.steps_run >= ref.steps_run

    store_ref = raw_store(eng_ref.store)
    store_got = raw_store(eng_got.store)
    for t in trials:
        leaf_ref = plan_ref.trial_paths[t.trial_id][-1]
        leaf_got = plan_got.trial_paths[t.trial_id][-1]
        assert_states_identical(
            store_ref.get(plan_ref.nodes[leaf_ref].ckpts[16]),
            store_got.get(plan_got.nodes[leaf_got].ckpts[16]))
        assert (plan_ref.nodes[leaf_ref].metrics[16]
                == plan_got.nodes[leaf_got].metrics[16])


# ---------------------------------------------------------------------------
# retry-bitwise assertion (the in-band verifier)
# ---------------------------------------------------------------------------

def test_assert_retry_identical():
    """With an injector attached, every re-put of a committed checkpoint
    is compared bit-for-bit against the committed blob: identical trees
    count in ``retries_verified``; a divergent recompute is an engine bug
    and must raise."""
    inj = FaultInjector(0)
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(horizon=40), n_workers=1,
                       fault_injector=inj)
    svc.submit(SPEC, GridTuner(_space(1).trials(40)[:1]))
    svc.close()
    eng = svc._engine
    disp = eng.dispatcher
    store = raw_store(eng.store)

    nid, node = next(iter(eng.plan.nodes.items()))
    step, cid = next(iter(node.ckpts.items()))
    committed = store.get(cid)
    path_key = eng.plan.path_key(nid)
    assert store.ckpt_id(path_key, step) == cid

    before = inj.retries_verified
    disp._assert_retry_identical(path_key, step, committed)
    assert inj.retries_verified == before + 1

    mutated = {k: (np.asarray(v) + 1 if np.issubdtype(
        np.asarray(v).dtype, np.number) else v)
        for k, v in committed.items()}
    with pytest.raises(RuntimeError, match="retry"):
        disp._assert_retry_identical(path_key, step, mutated)

    # unknown checkpoint: nothing committed yet, nothing to verify
    disp._assert_retry_identical("no-such-path", 999, committed)
    assert inj.retries_verified == before + 1


# ---------------------------------------------------------------------------
# session snapshots: unique tmp, v2/v3 migration, rotation + fallback
# ---------------------------------------------------------------------------

def _small_session():
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(horizon=80), n_workers=2)
    svc.submit(SPEC, GridTuner(_space(1).trials(80)))
    for _ in range(4):
        svc.step()
    return svc, capture_session(svc._engine)


def test_save_session_tmp_is_process_unique(tmp_path, monkeypatch):
    _, state = _small_session()
    seen = []
    real_replace = os.replace

    def spy(src, dst):
        seen.append(src)
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", spy)
    save_session(state, str(tmp_path / "s.pkl"))
    assert len(seen) == 1
    # two concurrent writers (two processes, or two threads of one) must
    # never share a tmp name
    assert f".tmp.{os.getpid()}." in seen[0]


def test_save_session_cleans_tmp_on_failure(tmp_path, monkeypatch):
    _, state = _small_session()

    def boom(src, dst):
        raise OSError("disk full")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError):
        save_session(state, str(tmp_path / "s.pkl"))
    assert list(tmp_path.iterdir()) == []


def test_v2_and_v3_snapshots_migrate():
    svc, state = _small_session()
    # v2: 3-tuple worker rows, none of the newer stats fields
    state.version = 2
    state.workers = [(w[0], w[1], w[2]) for w in state.workers]
    for f in ("stage_failures", "stage_retries", "workers_quarantined",
              "groups_degraded", "faults_injected", "wasted_gpu_seconds"):
        delattr(state.stats, f)
    m = migrate_session(state)
    assert m.version >= 4
    # v5 rows: (wid, busy, idle, mesh, failures, quarantines, q_until,
    # draining) — mesh, fault record and the front-door draining flag are
    # all backfilled
    assert all(len(row) == 8 for row in m.workers)
    assert m.workers[0][3] is None          # mesh backfilled
    assert m.workers[0][7] is False         # draining backfilled
    assert m.stats.stage_retries == 0 and m.stats.wasted_gpu_seconds == 0.0

    eng = restore_engine(m, SimulatedTrainer(horizon=80))
    assert [w.failures for w in eng.workers] == [0, 0]

    # v3: 4-tuple rows (mesh present, no fault-plane columns)
    _, state3 = _small_session()
    state3.version = 3
    state3.workers = [w[:4] for w in state3.workers]
    m3 = migrate_session(state3)
    assert all(len(row) == 8 for row in m3.workers)

    _, state1 = _small_session()
    state1.version = 1
    with pytest.raises(ValueError):
        migrate_session(state1)


def test_rotation_keeps_n_and_falls_back_on_corruption(tmp_path):
    _, state = _small_session()
    base = str(tmp_path / "sess.pkl")
    for _ in range(5):
        save_session_rotated(state, base, keep=3)
    slots = session_rotation(base)
    assert [seq for seq, _ in slots] == [5, 4, 3]     # newest first, keep=3

    # newest truncated -> falls back to the previous slot
    newest = slots[0][1]
    with open(newest, "r+b") as f:
        f.truncate(64)
    loaded, path = load_latest_session(base)
    assert path == slots[1][1]
    assert loaded.version == state.version

    # newest garbage (unpicklable), second truncated -> third still wins
    with open(newest, "wb") as f:
        f.write(b"not a pickle")
    with open(slots[1][1], "r+b") as f:
        f.truncate(10)
    loaded, path = load_latest_session(base)
    assert path == slots[2][1]

    # everything corrupt -> a FileNotFoundError naming the failures
    with open(slots[2][1], "wb") as f:
        f.write(b"nope")
    with pytest.raises(FileNotFoundError):
        load_latest_session(base)


def test_restore_latest_resumes_to_identical_stats(tmp_path):
    ref, _, _ = run_session(None, n_workers=2, second_study=False)

    base = str(tmp_path / "sess.pkl")
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(horizon=80), n_workers=2)
    svc.enable_auto_snapshot(base, every=25.0, keep=3)
    svc.submit(SPEC, GridTuner(_space().trials(80)))
    for _ in range(12):                    # interrupt mid-drain
        svc.step()
    assert session_rotation(base), "auto-snapshot never fired"
    del svc                                # the crash

    svc2 = StudyService.restore_latest(SearchPlanDB(), base,
                                       SimulatedTrainer(horizon=80))
    got = svc2.close()
    assert det(got) == det(ref)


# ---------------------------------------------------------------------------
# crash consistency end-to-end: SIGKILL mid-drain, restore, finish
# ---------------------------------------------------------------------------

_KILLED_SCRIPT = """
import os, signal, sys
sys.path.insert(0, {src!r})
sys.path.insert(0, {tests!r})
from test_faults import SPEC, _space
from repro.core import SearchPlanDB, StudyService
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridTuner

svc = StudyService(SearchPlanDB(), SimulatedTrainer(horizon=80),
                   n_workers=2, policy="fair_share")
svc.enable_auto_snapshot({base!r}, every=25.0, keep=3)
svc.submit(SPEC, GridTuner(_space().trials(80)))
svc.submit(SPEC, GridTuner(_space().trials(80)[:4]), at=200.0)
n = 0
while svc.step():
    n += 1
    if n == {kill_after}:
        os.kill(os.getpid(), signal.SIGKILL)   # no atexit, no flush
raise SystemExit("ran to completion before the kill point")
"""


def test_sigkill_then_restore_finishes_identically(tmp_path):
    """SIGKILL mid-drain (no graceful path at all), then restore from the
    newest readable rotation slot and finish: final EngineStats — by_study
    included — match an uninterrupted run."""
    ref, _, _ = run_session(None, n_workers=2)

    base = str(tmp_path / "sess.pkl")
    script = tmp_path / "killed.py"
    script.write_text(_KILLED_SCRIPT.format(
        src=os.path.join(REPO, "src"), tests=os.path.join(REPO, "tests"),
        base=base, kill_after=14))
    proc = subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == -signal.SIGKILL, proc.stderr[-2000:]
    assert session_rotation(base), "no snapshot survived the kill"

    svc = StudyService.restore_latest(SearchPlanDB(), base,
                                      SimulatedTrainer(horizon=80))
    got = svc.close()
    assert det(got) == det(ref)
    assert {k: (v.gpu_seconds, v.steps_run, v.instant_results)
            for k, v in got.by_study.items()} == \
           {k: (v.gpu_seconds, v.steps_run, v.instant_results)
            for k, v in ref.by_study.items()}


def test_sigterm_graceful_shutdown_snapshot(tmp_path):
    """satellite (c): the launcher's SIGTERM handler takes a final
    snapshot to --session before exiting; the snapshot resumes to the
    uninterrupted totals."""
    sess = str(tmp_path / "term.pkl")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    argv = [sys.executable, "-m", "repro.launch.serve_studies",
            "--studies", "2", "--steps", "60", "--workers", "2",
            "--arrival-gap", "600", "--sec-per-step", "10",
            "--session", sess, "--throttle", "0.25"]
    proc = subprocess.Popen(argv, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    try:
        import time
        time.sleep(2.5)                    # a few throttled steps in
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=60)
    finally:
        proc.kill()
    assert proc.returncode == 0, out[-2000:]
    assert "final snapshot" in out
    assert os.path.exists(sess)

    # the launcher is gateway-driven now: the final snapshot is a v5
    # gateway envelope holding every live session
    from repro.frontdoor import StudyGateway
    gw = StudyGateway.restore(
        SearchPlanDB(), sess,
        SimulatedTrainer(base_seconds_per_step=10.0, horizon=60))
    gw.join()
    [(_, got)] = gw.close()

    db = SearchPlanDB()
    ref_svc = StudyService(db, SimulatedTrainer(base_seconds_per_step=10.0,
                                                horizon=60), n_workers=2)
    spec = StudySpec("resnet20", "cifar10", ("lr", "bs"))
    from repro.launch.serve_studies import _space as launcher_space
    for i in range(2):
        ref_svc.submit(spec, GridTuner(launcher_space(i, 60).trials(60)),
                       at=i * 600.0)
    ref = ref_svc.close()
    assert det(got) == det(ref)


# ---------------------------------------------------------------------------
# launcher fault-injection surface
# ---------------------------------------------------------------------------

def test_serve_studies_inject_faults(monkeypatch, capsys):
    from repro.launch import serve_studies
    monkeypatch.setattr(sys, "argv",
                        ["serve_studies", "--studies", "2", "--workers", "4",
                         "--steps", "60", "--arrival-gap", "600",
                         "--sec-per-step", "10",
                         "--inject-faults", "7",
                         "--fault-rates", "0.3,0.15,0.02"])
    serve_studies.main()
    out = capsys.readouterr().out
    assert "fault plane:" in out
    assert "served:" in out
