"""Study service layer: long-lived sessions, dynamic admission, durable
resume (ISSUE 5 — the §6.2 multi-study scenario under continuous traffic).
"""

import pytest

from repro.core import (Constant, Exponential, MultiStep, SearchPlanDB,
                        StepLR, Study, StudyService, StudySpec, Warmup,
                        run_studies)
from repro.core.hpseq import HpConfig
from repro.core.trainer import SimulatedTrainer
from repro.core.trial import Trial
from repro.core.tuners import GridSearchSpace, GridTuner
from repro.train.checkpoint import CheckpointStore

SPEC = StudySpec("m", "d", ("lr", "bs"))


def det(stats):
    """Deterministic view of EngineStats: ckpt_{save,load}_seconds are real
    wall-clock timers (perf_counter) and vary run to run even on the
    simulator, and the checkpoint-plane v2 counters describe the *physical*
    store — cache temperature, delta-vs-full mix and tier placement
    legitimately differ between an uninterrupted run and a restored one
    (a fresh store re-reads blobs it didn't write and re-bases delta
    chains) — everything else, by_study included, must replay exactly."""
    import dataclasses
    return dataclasses.replace(
        stats, ckpt_save_seconds=0.0, ckpt_load_seconds=0.0,
        ckpt_delta_bytes=0, ckpt_full_bytes=0, ckpt_logical_bytes=0,
        ckpt_bytes_written=0, ckpt_delta_commits=0, ckpt_delta_rebases=0,
        ckpt_mem_hits=0, ckpt_disk_hits=0, ckpt_remote_hits=0,
        ckpt_store_misses=0, ckpt_tier_promotions=0, ckpt_tier_demotions=0,
        ckpt_tmp_reclaimed=0, d2d_handoffs=0)


def space():
    return GridSearchSpace(
        fns={"lr": [Constant(0.1), StepLR(0.1, 0.1, [100, 150]),
                    Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135])),
                    Warmup(5, 0.1, Exponential(0.1, 0.95))],
             "bs": [Constant(128), MultiStep(128, [70], values=[128, 256])]})


def mk(lr, steps):
    return Trial(HpConfig({"lr": lr}), steps)


# ---------------------------------------------------------------------------
# session basics
# ---------------------------------------------------------------------------


def test_upfront_service_equals_run_studies():
    """Submitting everything at t=0 through the session is event-for-event
    the legacy batch path: identical stats."""
    def batch():
        db = SearchPlanDB()
        pairs = [(Study.from_spec(db, SPEC), GridTuner(space().trials(150)))
                 for _ in range(2)]
        return run_studies(pairs, SimulatedTrainer(), n_workers=4)

    def service():
        db = SearchPlanDB()
        svc = StudyService(db, SimulatedTrainer(), n_workers=4)
        for _ in range(2):
            svc.submit(SPEC, GridTuner(space().trials(150)))
        return svc.close()

    assert det(batch()) == det(service())


def test_future_lifecycle_and_result():
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(), n_workers=4)
    fut = svc.submit(SPEC, GridTuner(space().trials(100)))
    assert fut.status == "queued" and not fut.done()
    st = fut.result()
    assert fut.done() and fut.tuner.is_done()
    assert st.gpu_seconds > 0 and st.steps_run > 0 and st.trials == 8
    assert svc.stats.by_study["study-0"] is st
    svc.close()


def test_session_survives_quiescence_and_reuses_forest():
    """Quiescence is not termination: a drained session admits late studies
    and serves them from the accumulated forest."""
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(), n_workers=4)
    svc.submit(SPEC, GridTuner(space().trials(150)))
    svc.join()
    assert svc.quiescent
    steps_before = svc.stats.steps_run

    fut2 = svc.submit(SPEC, GridTuner(space().trials(150)))  # identical space
    stats = svc.close()
    assert fut2.done()
    # every request answered straight from plan metrics — zero new training
    assert stats.steps_run == steps_before
    assert stats.study("study-1").instant_results == 8
    assert stats.study("study-1").steps_run == 0


def test_submit_after_close_and_key_mismatch_raise():
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(), n_workers=2)
    svc.submit(SPEC, GridTuner(space().trials(60)))
    with pytest.raises(ValueError, match="one StudyService drives one"):
        svc.submit(StudySpec("other", "d", ("lr", "bs")),
                   GridTuner(space().trials(60)))
    with pytest.raises(ValueError, match="already submitted"):
        svc.submit(SPEC, GridTuner(space().trials(60)), study_id="study-0")
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(SPEC, GridTuner(space().trials(60)))


def test_run_studies_key_mismatch_raises_valueerror():
    # satellite: a bare assert would vanish under `python -O`
    db = SearchPlanDB()
    s1 = Study.create(db, "m1", "d", ("lr",))
    s2 = Study.create(db, "m2", "d", ("lr",))
    with pytest.raises(ValueError, match="common study key"):
        run_studies([(s1, GridTuner([])), (s2, GridTuner([]))],
                    SimulatedTrainer())


# ---------------------------------------------------------------------------
# dynamic admission
# ---------------------------------------------------------------------------


def staggered_run(share, n_studies=3, offset=40.0, steps=160):
    # 2 workers: dispatch keeps happening past the arrival times, so late
    # studies genuinely merge into (and get credited on) in-flight work
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(horizon=steps), n_workers=2,
                       share=share)
    futs = [svc.submit(SPEC, GridTuner(space().trials(steps)), at=i * offset)
            for i in range(n_studies)]
    return svc.close(), futs


def test_staggered_admission_merges_with_inflight_forest():
    """A study arriving mid-drain merges into the live forest: physical
    work well below the salted (trial-based) baseline."""
    shared, futs = staggered_run(share=True)
    salted, _ = staggered_run(share=False)
    assert all(f.done() for f in futs)
    assert shared.steps_run < salted.steps_run
    assert shared.gpu_seconds < salted.gpu_seconds
    # split-credited execution seconds can never exceed the engine total
    # (resume-load overhead is engine-level only)
    assert sum(s.gpu_seconds for s in shared.by_study.values()) \
        <= shared.gpu_seconds + 1e-6
    # on-behalf-of step counts exceed physical steps exactly when shared
    assert sum(s.steps_run for s in shared.by_study.values()) \
        > shared.steps_run


def test_arrival_before_fork_point_equals_upfront():
    """An arrival that lands before the shared prefix completes costs
    exactly what upfront submission would have: same physical steps, same
    GPU-seconds (the prefix is trained once either way)."""
    a = mk(MultiStep(0.1, [100], values=[0.1, 0.05]), 200)
    b = mk(MultiStep(0.1, [100], values=[0.1, 0.01]), 400)

    def run(stagger):
        db = SearchPlanDB()
        svc = StudyService(db, SimulatedTrainer(), n_workers=1)
        svc.submit(SPEC, GridTuner([a]))
        svc.submit(SPEC, GridTuner([b]), at=1.0 if stagger else None)
        return svc.close()

    upfront, late = run(False), run(True)
    assert late.steps_run == upfront.steps_run == 500   # 100 + 100 + 300
    assert late.gpu_seconds == pytest.approx(upfront.gpu_seconds)
    assert late.ckpt_loads == upfront.ckpt_loads


# ---------------------------------------------------------------------------
# cancel / detach
# ---------------------------------------------------------------------------


def test_cancel_mid_run_releases_nodes_into_gc():
    db = SearchPlanDB()
    store = CheckpointStore()
    svc = StudyService(db, SimulatedTrainer(), n_workers=2, store=store)
    fut_a = svc.submit(SPEC, GridTuner([mk(Constant(0.1), 200),
                                        mk(Constant(0.2), 200)]))
    fut_b = svc.submit(SPEC, GridTuner([mk(Constant(0.05), 400),
                                        mk(Constant(0.02), 400)]))
    svc.run_until(150.0)
    assert not svc.quiescent
    assert fut_b.cancel()
    assert fut_b.cancelled()
    assert fut_b.cancel()          # idempotent once cancelled
    stats = svc.close()

    assert fut_a.done()
    assert fut_b.status == "cancelled"
    with pytest.raises(RuntimeError, match="cancelled"):
        fut_b.result()
    # B's exclusive nodes were released into checkpoint GC
    assert stats.ckpt_evictions > 0
    plan = db.get(SPEC.key)
    assert plan.pending_requests() == []
    for t in fut_b.tuner.trials:
        assert t.trial_id not in plan.trial_paths
    # A's checkpoints survive in the store; B's are gone
    live_cids = {cid for nid, n in plan.nodes.items() if n.refcount > 0
                 for cid in n.ckpts.values()}
    assert all(store.contains(c) for c in live_cids)


def test_cancel_spares_nodes_shared_with_live_study():
    db = SearchPlanDB()
    store = CheckpointStore()
    svc = StudyService(db, SimulatedTrainer(), n_workers=1, store=store)
    shared_cfg = MultiStep(0.1, [100], values=[0.1, 0.05])
    fut_a = svc.submit(SPEC, GridTuner([mk(shared_cfg, 200)]))
    # B shares the [0, 100) prefix node with A, then diverges
    fut_b = svc.submit(SPEC, GridTuner(
        [mk(MultiStep(0.1, [100], values=[0.1, 0.01]), 400)]))
    svc.run_until(150.0)
    fut_b.cancel()
    svc.close()
    assert fut_a.done()
    plan = db.get(SPEC.key)
    # the shared prefix node is still referenced by A and keeps its ckpt
    prefix = [n for n in plan.nodes.values() if n.start == 0][0]
    assert prefix.refcount > 0
    assert all(store.contains(c) for c in prefix.ckpts.values())


# ---------------------------------------------------------------------------
# durable resume
# ---------------------------------------------------------------------------


def build_session(db):
    svc = StudyService(db, SimulatedTrainer(), n_workers=4)
    svc.submit(SPEC, GridTuner(space().trials(200)))
    svc.submit(SPEC, GridTuner(space().trials(160)), at=80.0)
    return svc


def test_snapshot_restore_resumes_identically(tmp_path):
    """The acceptance check: a half-finished session restored from a
    snapshot finishes with EngineStats (per-study gpu_seconds, steps_run
    included) identical to the uninterrupted run."""
    db = SearchPlanDB()
    svc = build_session(db)
    svc.run_until(150.0)          # half-finished; study-1 admitted at t=80
    assert not svc.quiescent
    path = str(tmp_path / "session.pkl")
    svc.snapshot(path)
    reference = svc.close()       # the uninterrupted run

    db2 = SearchPlanDB()
    svc2 = StudyService.restore(db2, path, SimulatedTrainer())
    assert not svc2.quiescent
    assert [f.study_id for f in svc2.futures] == ["study-0", "study-1"]
    resumed = svc2.close()

    assert det(resumed) == det(reference)  # full equality, by_study included
    assert resumed.by_study["study-0"] == reference.by_study["study-0"]
    assert resumed.by_study["study-1"] == reference.by_study["study-1"]
    assert all(f.done() for f in svc2.futures)


def test_snapshot_restore_with_directory_store(tmp_path):
    """Directory-backed stores persist blobs themselves: the snapshot only
    records the committed index, and restore serves resumes from disk."""
    db = SearchPlanDB()
    store = CheckpointStore(str(tmp_path / "ckpts"))
    svc = StudyService(db, SimulatedTrainer(), n_workers=4, store=store)
    svc.submit(SPEC, GridTuner(space().trials(200)))
    svc.run_until(120.0)
    path = str(tmp_path / "session.pkl")
    svc.snapshot(path)
    reference = svc.close()

    store2 = CheckpointStore(str(tmp_path / "ckpts"))
    svc2 = StudyService.restore(SearchPlanDB(), path, SimulatedTrainer(),
                                store=store2)
    resumed = svc2.close()
    assert det(resumed) == det(reference)
    assert resumed.ckpt_misses == reference.ckpt_misses


def test_restore_with_emptied_store_degrades_to_recompute(tmp_path):
    """A store that lost blobs since the snapshot costs recomputation, not
    a crash: stale plan entries are forgotten eagerly at restore."""
    db = SearchPlanDB()
    svc = build_session(db)
    svc.run_until(150.0)
    path = str(tmp_path / "session.pkl")
    svc.snapshot(path)
    reference = svc.close()

    state_breaking_store = CheckpointStore()   # fresh and EMPTY memory store
    import repro.core.engine.session as sess
    state = sess.load_session(path)
    state.store_mem = None                     # simulate losing every blob
    state.store_cids = set()
    sess.save_session(state, path)
    svc2 = StudyService.restore(SearchPlanDB(), path, SimulatedTrainer(),
                                store=state_breaking_store)
    resumed = svc2.close()
    assert all(f.done() for f in svc2.futures)
    # completes correctly, but pays recompute for the lost checkpoints
    assert resumed.steps_run >= reference.steps_run


def test_snapshot_requires_submission():
    svc = StudyService(SearchPlanDB(), SimulatedTrainer())
    with pytest.raises(RuntimeError, match="nothing submitted"):
        svc.snapshot("nowhere.pkl")
