"""Suite-wide plumbing: the skip-budget guard.

``pytest --max-skips N`` fails an otherwise-green run that reports more
than N skipped tests.  CI passes ``--max-skips 0`` (hypothesis and
``repro.dist`` are installed there, so nothing may skip); the bare local
container's documented allowance is the four property-half placeholders
(see the verify skill notes).
"""

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--max-skips", action="store", default=None, type=int,
        metavar="N",
        help="fail the run if more than N tests are reported as skipped "
             "(catches silently-rotting importorskip guards)")


def pytest_sessionfinish(session, exitstatus):
    budget = session.config.getoption("--max-skips")
    if budget is None or exitstatus != 0:
        return
    reporter = session.config.pluginmanager.get_plugin("terminalreporter")
    if reporter is None:
        return
    skipped = len(reporter.stats.get("skipped", []))
    if skipped > budget:
        reporter.write_line(
            f"skip budget exceeded: {skipped} skipped > allowed {budget} "
            f"(see --max-skips)", red=True)
        session.exitstatus = 1
