"""Stage-tree generation — Algorithm 1 (§3)."""

from repro.core.hpseq import Constant, HpConfig, MultiStep
from repro.core.searchplan import Request, SearchPlan
from repro.core.stagetree import build_stage_tree
from repro.core.trial import Trial


def mk(lr, steps):
    return Trial(HpConfig({"lr": lr}), steps)


def submit_all(plan, *trials):
    return [plan.submit(t) for t in trials]


def test_single_trial_single_stage():
    plan = SearchPlan()
    plan.submit(mk(Constant(0.1), 100))
    tree = build_stage_tree(plan)
    assert len(tree) == 1
    (st,) = tree.stages.values()
    assert (st.start, st.stop, st.report) == (0, 100, True)
    assert st.resume is None and st.parent is None


def test_shared_prefix_emits_split_stages():
    """Constant(0.1)@100 and MultiStep(0.1→0.01@100)@200 share [0,100)."""
    plan = SearchPlan()
    a = mk(Constant(0.1), 100)
    b = mk(MultiStep(0.1, [100], values=[0.1, 0.01]), 200)
    submit_all(plan, a, b)
    tree = build_stage_tree(plan)
    # stages: root[0→100] (report for a), child 0.01 [100→200] (report for b)
    assert len(tree) == 2
    stages = sorted(tree.stages.values(), key=lambda s: s.start)
    assert (stages[0].start, stages[0].stop, stages[0].report) == (0, 100, True)
    assert (stages[1].start, stages[1].stop, stages[1].report) == (100, 200, True)
    assert stages[1].parent == stages[0].stage_id
    assert tree.total_steps() == 200           # zero redundancy


def test_resume_from_checkpoint():
    plan = SearchPlan()
    t = mk(Constant(0.1), 200)
    node, _, _ = plan.submit(t)
    plan.record_result(node.node_id, 120, "ck120", None)   # mid checkpoint
    tree = build_stage_tree(plan)
    (st,) = tree.stages.values()
    assert st.resume == (node.node_id, 120)
    assert (st.start, st.stop) == (120, 200)


def test_defer_when_running():
    plan = SearchPlan()
    t = mk(Constant(0.1), 100)
    node, _, _ = plan.submit(t)
    plan.mark_running([Request(node.node_id, 100)])
    # a second request at a shorter step on the same (running) node
    t2 = mk(Constant(0.1), 50)
    plan.submit(t2)
    tree = build_stage_tree(plan)
    assert len(tree) == 0                      # deferred, Algorithm 1 line 15


def test_eval_only_stage_when_ckpt_exists_but_no_metrics():
    plan = SearchPlan()
    t = mk(Constant(0.1), 100)
    node, _, _ = plan.submit(t)
    plan.record_result(node.node_id, 100, "ck100", None)   # ckpt, no metrics
    tree = build_stage_tree(plan)
    (st,) = tree.stages.values()
    assert st.steps == 0 and st.report
    assert st.resume == (node.node_id, 100)


def test_deep_chain_resumes_nearest_ancestor_ckpt():
    """FindLatestCheckpoint recursion across three nodes (Figure 6/7)."""
    plan = SearchPlan()
    t = mk(MultiStep(0.1, [20, 40], values=[0.1, 0.05, 0.01]), 60)
    leaf, _, _ = plan.submit(t)
    path = plan.path_to_root(leaf.node_id)
    assert len(path) == 3
    plan.record_result(path[0].node_id, 10, "ck10", None)  # ckpt in root
    tree = build_stage_tree(plan)
    stages = sorted(tree.stages.values(), key=lambda s: s.start)
    assert stages[0].resume == (path[0].node_id, 10)
    assert [s.start for s in stages] == [10, 20, 40]
    assert stages[-1].report
    # chain: each later stage parented on the previous
    assert stages[1].parent == stages[0].stage_id
    assert stages[2].parent == stages[1].stage_id


def test_multiple_requests_same_node_cut_stages():
    plan = SearchPlan()
    a = mk(Constant(0.1), 50)
    b = mk(Constant(0.1), 100)
    submit_all(plan, a, b)
    tree = build_stage_tree(plan)
    stages = sorted(tree.stages.values(), key=lambda s: s.start)
    assert [(s.start, s.stop, s.report) for s in stages] == [
        (0, 50, True), (50, 100, True)]
    assert tree.total_steps() == 100
