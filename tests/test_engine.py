"""Execution engine: tuners on the simulator, stage vs trial accounting."""

import pytest

from repro.core import (Constant, Exponential, HpConfig, MultiStep,
                        SearchPlanDB, StepLR, Study, Warmup, merge_rate,
                        run_studies)
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import (ASHATuner, GridSearchSpace, GridTuner,
                               HyperbandTuner, MedianStoppingTuner, PBTTuner,
                               SHATuner)


def space():
    return GridSearchSpace(
        fns={"lr": [Constant(0.1), StepLR(0.1, 0.1, [100, 150]),
                    Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135])),
                    Warmup(5, 0.1, Exponential(0.1, 0.95))],
             "bs": [Constant(128), MultiStep(128, [70], values=[128, 256])]})


def run(tuner_cls, share=True, n_workers=8, steps=200, **kw):
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr", "bs"))
    trials = space().trials(steps)
    if tuner_cls is GridTuner:
        tuner = GridTuner(trials)
    elif tuner_cls is SHATuner:
        tuner = SHATuner(trials, min_steps=25, max_steps=steps, eta=2)
    elif tuner_cls is ASHATuner:
        tuner = ASHATuner(trials, min_steps=25, max_steps=steps, eta=2)
    elif tuner_cls is HyperbandTuner:
        tuner = HyperbandTuner(trials, max_steps=steps, eta=4)
    elif tuner_cls is MedianStoppingTuner:
        tuner = MedianStoppingTuner(trials, milestones=[50, 100, steps])
    else:
        raise AssertionError(tuner_cls)
    stats = st.run(tuner, SimulatedTrainer(), n_workers=n_workers, share=share,
                   **kw)
    return stats, tuner, db.get(st.key)


@pytest.mark.parametrize("tuner_cls", [GridTuner, SHATuner, ASHATuner,
                                       HyperbandTuner, MedianStoppingTuner])
def test_tuners_complete_and_find_best(tuner_cls):
    stats, tuner, plan = run(tuner_cls)
    assert tuner.is_done()
    assert stats.gpu_seconds > 0 and stats.end_to_end > 0
    best = getattr(tuner, "best", None) or getattr(tuner, "best_cfg", None)
    assert best is not None


def test_stage_saves_gpu_hours_vs_trial_grid():
    """Grid: GPU-hour saving ≈ merge rate p (§6.1 headline check)."""
    trials = space().trials(200)
    p = merge_rate(trials)
    s_stage, _, _ = run(GridTuner, share=True)
    s_trial, _, _ = run(GridTuner, share=False)
    saving = s_trial.gpu_seconds / s_stage.gpu_seconds
    assert saving > 1.05
    # within 15% of p (checkpoint/eval overheads shave a little)
    assert saving == pytest.approx(p, rel=0.15)
    # stage mode trains strictly fewer steps
    assert s_stage.steps_run < s_trial.steps_run


def test_sha_saves_at_least_grid_rate():
    s_stage, t_stage, _ = run(SHATuner, share=True)
    s_trial, t_trial, _ = run(SHATuner, share=False)
    assert s_trial.gpu_seconds / s_stage.gpu_seconds > 1.1


def test_stage_tree_is_lossless_for_metrics():
    """Merged trials observe the same metric the solo run would produce
    (the simulator's state is a function of the hp trajectory only)."""
    _, t_share, plan = run(GridTuner, share=True)
    _, t_solo, _ = run(GridTuner, share=False)
    # compare best scores: identical hp → identical deterministic metrics up
    # to the path-keyed jitter, which differs under salting; so check instead
    # that every shared leaf metric is present and finite
    for tid, path in plan.trial_paths.items():
        leaf = plan.nodes[path[-1]]
        assert leaf.metrics, tid


def test_pbt_exploit_reuses_winner_prefix():
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr",))
    configs = [HpConfig({"lr": Constant(v)}) for v in (0.2, 0.1, 0.05, 0.01)]
    tuner = PBTTuner(configs, interval=20, generations=4)
    stats = st.run(tuner, SimulatedTrainer(), n_workers=4)
    assert tuner.is_done()
    plan = db.get(st.key)
    # every member trains interval steps per generation — never more
    total = 4 * 4 * 20
    assert stats.steps_run <= total
    assert tuner.best_score > 0
    # at least one exploit happened: a loser's new trial rides the winner's
    # path, so some plan node is shared by 2+ trials (weight copy for free)
    assert any(len(n.trials) >= 2 for n in plan.nodes.values())


def test_multi_study_merging():
    """§6.2: studies with overlapping spaces share computation."""
    def one_study_stats():
        db = SearchPlanDB()
        st = Study.create(db, "m", "d", ("lr", "bs"))
        return st.run(GridTuner(space().trials(150)), SimulatedTrainer(),
                      n_workers=8)

    s1 = one_study_stats()

    db = SearchPlanDB()
    studies = []
    for i in range(2):
        st = Study.create(db, "m", "d", ("lr", "bs"))
        studies.append((st, GridTuner(space().trials(150))))
    s2 = run_studies(studies, SimulatedTrainer(), n_workers=8)
    # second identical study is nearly free: 2 studies cost << 2× one study
    assert s2.gpu_seconds < 1.35 * s1.gpu_seconds


def test_kill_cancels_pending_requests():
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr", "bs"))
    trials = space().trials(200)
    tuner = SHATuner(trials, min_steps=25, max_steps=200, eta=4)
    stats = st.run(tuner, SimulatedTrainer(), n_workers=2)
    plan = db.get(st.key)
    # after completion no dangling pending requests
    assert plan.pending_requests() == []


def test_checkpoint_store_dedup():
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr", "bs"))
    from repro.train.checkpoint import CheckpointStore
    store = CheckpointStore()
    stats = st.run(GridTuner(space().trials(100)), SimulatedTrainer(),
                   n_workers=4, store=store)
    assert store.puts >= len(store._mem)       # shared stages dedup puts
