"""Critical-path scheduler (§4.3)."""

from repro.core.hpseq import Constant, HpConfig, MultiStep
from repro.core.scheduler import CriticalPathScheduler
from repro.core.searchplan import SearchPlan
from repro.core.stagetree import build_stage_tree
from repro.core.trial import Trial


def make_plan():
    plan = SearchPlan()
    # shared prefix [0,100); branches of length 100 and 300
    short = Trial(HpConfig({"lr": MultiStep(0.1, [100], values=[0.1, 0.05])}), 200)
    long = Trial(HpConfig({"lr": MultiStep(0.1, [100], values=[0.1, 0.01])}), 400)
    plan.submit(short)
    plan.submit(long)
    return plan


def test_critical_path_takes_longest_branch_first():
    plan = make_plan()
    tree = build_stage_tree(plan)
    sched = CriticalPathScheduler()
    taken = set()
    path1 = sched.next_path(plan, tree, taken)
    # first chain = root + the 300-step branch (the critical path)
    assert sum(s.steps for s in path1) == 400
    path2 = sched.next_path(plan, tree, taken)
    assert sum(s.steps for s in path2) == 100  # remaining short branch
    assert sched.next_path(plan, tree, taken) is None


def test_chains_are_parent_connected():
    plan = make_plan()
    tree = build_stage_tree(plan)
    sched = CriticalPathScheduler()
    for path in sched.assign(plan, tree, 4):
        for prev, cur in zip(path, path[1:]):
            assert cur.parent == prev.stage_id


def test_profile_weighting_changes_critical_path():
    plan = SearchPlan()
    a = Trial(HpConfig({"lr": MultiStep(0.1, [100], values=[0.1, 0.05])}), 200)
    b = Trial(HpConfig({"lr": MultiStep(0.1, [100], values=[0.1, 0.01])}), 150)
    na, _, _ = plan.submit(a)
    nb, _, _ = plan.submit(b)
    # b's branch is shorter in steps but 10× slower per step
    plan.record_profile(nb.node_id, 10.0)
    tree = build_stage_tree(plan)
    sched = CriticalPathScheduler()
    path1 = sched.next_path(plan, tree, set())
    leaf = path1[-1]
    assert leaf.node_id == nb.node_id          # time-weighted critical path


def test_assign_disjoint():
    plan = make_plan()
    tree = build_stage_tree(plan)
    sched = CriticalPathScheduler()
    paths = sched.assign(plan, tree, 8)
    seen = set()
    for p in paths:
        for s in p:
            assert s.stage_id not in seen
            seen.add(s.stage_id)
    assert seen == set(tree.stages)            # full coverage
