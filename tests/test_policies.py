"""Pluggable scheduling policies: FIFO, weighted fan-out, per-study fair
share — plus the policy factory and Study wiring."""

import pytest

from repro.core import SearchPlan, SearchPlanDB, Study, build_stage_tree, run_studies
from repro.core.hpseq import Constant, HpConfig, MultiStep
from repro.core.scheduler import (POLICIES, CriticalPathScheduler,
                                  FIFOScheduler, FairShareScheduler,
                                  WeightedFanoutScheduler, make_policy)
from repro.core.trainer import SimulatedTrainer
from repro.core.trial import Trial
from repro.core.tuners import GridTuner


def mk(lr, steps):
    return Trial(HpConfig({"lr": lr}), steps)


def branching_plan():
    plan = SearchPlan()
    short = mk(MultiStep(0.1, [100], values=[0.1, 0.05]), 200)
    long = mk(MultiStep(0.1, [100], values=[0.1, 0.01]), 400)
    plan.submit(short)
    plan.submit(long)
    return plan


def test_factory_and_registry():
    for name in ("critical_path", "weighted_fanout", "fifo", "fair_share"):
        assert name in POLICIES
        assert make_policy(name).next_path is not None
    with pytest.raises(ValueError):
        make_policy("round_robin")


def test_fifo_takes_first_submitted_branch_first():
    plan = branching_plan()
    tree = build_stage_tree(plan)
    paths = FIFOScheduler().assign(plan, tree, 4)
    # chain 1: root + the FIRST-submitted branch (200 total), regardless of
    # the 400-step critical path
    assert sum(s.steps for s in paths[0]) == 200
    assert sum(s.steps for s in paths[1]) == 300
    # disjoint full coverage, chains parent-connected
    seen = set()
    for p in paths:
        for prev, cur in zip(p, p[1:]):
            assert cur.parent == prev.stage_id
        for s in p:
            assert s.stage_id not in seen
            seen.add(s.stage_id)
    assert seen == set(tree.stages)


def test_weighted_fanout_matches_legacy_flag():
    plan = branching_plan()
    legacy = CriticalPathScheduler(weighted=True).assign(
        plan, build_stage_tree(plan), 4)
    new = WeightedFanoutScheduler().assign(plan, build_stage_tree(plan), 4)
    assert [[s.stage_id for s in p] for p in legacy] == \
        [[s.stage_id for s in p] for p in new]


def test_fair_share_prefers_least_served_study():
    plan = SearchPlan()
    # study A: two long disjoint trials; study B: one short trial
    a1 = mk(Constant(0.1), 400)
    a2 = mk(Constant(0.2), 400)
    b1 = mk(Constant(0.05), 100)
    plan.submit(a1, study="A")
    plan.submit(a2, study="A")
    plan.submit(b1, study="B")
    tree = build_stage_tree(plan)
    sched = FairShareScheduler()
    paths = sched.assign(plan, tree, 3)
    serving = [plan.studies_of_trial(next(iter(
        plan.node(p[0].node_id).trials))) for p in paths]
    # chain 1 goes to A (tie on usage, critical path breaks it); chain 2 must
    # serve the not-yet-served study B even though A has the longer remainder
    assert serving[0] == {"A"}
    assert serving[1] == {"B"}
    assert serving[2] == {"A"}
    assert sched.usage["A"] > sched.usage["B"] > 0


def test_fair_share_splits_shared_chain_cost():
    """ROADMAP split-charging: a chain shared by k studies charges each of
    them 1/k of its estimated cost — and refunds undo exactly the split."""
    plan = SearchPlan()
    # identical trial submitted by two studies: fully shared nodes
    plan.submit(mk(Constant(0.1), 100), study="A")
    plan.submit(mk(Constant(0.1), 100), study="B")
    # a trial only study B runs
    plan.submit(mk(Constant(0.3), 50), study="B")
    tree = build_stage_tree(plan)
    sched = FairShareScheduler()
    paths = sched.assign(plan, tree, 4)
    assert sum(len(p) for p in paths) == len(tree.stages)
    # shared 100-step chain: 50 s to each study; B additionally pays its
    # exclusive 50-step chain in full
    assert sched.usage["A"] == pytest.approx(50.0)
    assert sched.usage["B"] == pytest.approx(100.0)
    for p in paths:
        sched.on_stages_unassigned(plan, p)
    assert sched.usage["A"] == pytest.approx(0.0)
    assert sched.usage["B"] == pytest.approx(0.0)


def test_fair_share_engine_run_completes():
    db = SearchPlanDB()
    studies = []
    for i in range(2):
        st = Study.create(db, "m", "d", ("lr",))
        trials = [mk(Constant(0.01 * (i + 1) + 0.005 * j), 60)
                  for j in range(3)]
        studies.append((st, GridTuner(trials)))
    stats = run_studies(studies, SimulatedTrainer(), n_workers=2,
                        policy="fair_share")
    assert stats.gpu_seconds > 0 and stats.end_to_end > 0
    plan = db.get(studies[0][0].key)
    assert plan.pending_requests() == []


def test_fair_share_refunds_deferred_and_truncated_chains():
    """Chains cut or deferred by the dispatcher must be refunded: usage must
    reflect executed work only, never double-charge rescheduled stages."""
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr",))
    trials = [
        mk(Constant(0.1), 50),
        mk(MultiStep(0.1, [100], values=[0.1, 0.05]), 200),
        mk(MultiStep(0.1, [100], values=[0.1, 0.02]), 150),
    ]
    sched = FairShareScheduler()
    tuner = GridTuner(trials)
    eng = st.engine(SimulatedTrainer(), n_workers=2, policy=sched,
                    max_steps_per_chain=40)
    stats = eng.run([tuner])
    assert tuner.is_done()
    assert stats.chains_deferred >= 1
    # all work ran under one study: its net charge equals the executed
    # stage seconds (1 s/step simulator), with no phantom re-charges
    assert set(sched.usage) == {"study-0"}
    assert sched.usage["study-0"] == pytest.approx(stats.steps_run, rel=1e-6)


def test_study_engine_policy_by_name():
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr",))
    eng = st.engine(SimulatedTrainer(), policy="fifo")
    assert isinstance(eng.scheduler, FIFOScheduler)
    eng2 = st.engine(SimulatedTrainer(), weighted_paths=True)
    assert isinstance(eng2.scheduler, CriticalPathScheduler)
    assert eng2.scheduler.weighted
