"""Distribution plane v2: mesh-aware workers (ROADMAP item 2).

A :class:`Worker` owns a device set (:class:`WorkerMesh`); placement
routes chains and sibling-chain groups through the scheduling policy's
hint and the backend's divisibility gate instead of hardwiring
``idle[0]``; boundary states hand off device-to-device between same-host
workers without a store round-trip.  These tests pin:

* the descriptor itself (validation, pickling, the planner helper);
* placement: policy hints trade batch width against shard width,
  incompatible meshes are rejected (and an all-incompatible fleet
  degrades to replicated execution instead of starving);
* the dispatcher bugfixes this plane flushed out — a deferred chain
  returns its worker to the in-round pool, sibling-group placement goes
  through the policy, and a dedup'd sibling resume is copied before
  fan-out;
* d2d handoff: host-local hits bypass the store (``d2d_handoffs``),
  cross-host and backend-declined transfers fall back to it, and the
  virtual-clock accounting is identical either way;
* fleet equivalences: a 1-device-mesh fleet replays a thread fleet's
  stats exactly, session snapshots round-trip the meshes, and (in a
  subprocess with forced host devices) a stage sharded over a 4-device
  mesh is bitwise-identical to the unsharded run.
"""

import dataclasses
import os
import pickle
import subprocess
import sys

import pytest

from repro.core import SearchPlanDB, Study, StudyService, StudySpec
from repro.core.engine.dispatch import Dispatcher, Worker
from repro.core.engine.engine import EngineStats
from repro.core.engine.events import EventLoop
from repro.core.hpseq import Constant, HpConfig, MultiStep
from repro.core.scheduler import CriticalPathScheduler
from repro.core.searchplan import SearchPlan
from repro.core.trainer import SimulatedTrainer, StageContext
from repro.core.trial import Trial
from repro.core.tuners import GridTuner
from repro.dist.meshes import WorkerMesh, plan_worker_meshes
from repro.train.checkpoint import CheckpointStore

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


class BatchedSim(SimulatedTrainer):
    supports_batched_stages = True


class PickySim(BatchedSim):
    """Accepts only thread workers / trivial meshes — every real mesh is
    rejected by the placement gate."""

    def mesh_compatible(self, mesh, ctxs):
        return mesh is None or mesh.n_devices == 1


def make_dispatcher(plan, backend, workers, store=None, **kw):
    return Dispatcher(plan, backend, CriticalPathScheduler(),
                      store if store is not None else CheckpointStore(),
                      EventLoop(), EngineStats(), workers, **kw)


def sib_trial(tail_lr, total=40):
    return Trial(HpConfig({"lr": MultiStep(0.1, [20],
                                           values=[0.1, tail_lr])}), total)


def seeded_sibling_plan(store, values=(0.05, 0.02, 0.01)):
    """Three sibling trials forking at step 20, with the shared prefix
    already trained and checkpointed in ``store`` — the tails are a ready
    sibling group resuming from one cid."""
    backend = SimulatedTrainer()
    plan = SearchPlan()
    sibs = [sib_trial(v) for v in values]
    for t in sibs:
        plan.submit(t)
    shared = plan.trial_paths[sibs[0].trial_id][0]
    node = plan.node(shared)
    ctx = StageContext(node_id=shared, desc=node.desc,
                       node_start=node.start, start=0, stop=20,
                       path_key=plan.path_key(shared))
    state = backend.run_stage(backend.init_state(), ctx)
    cid = store.put(plan.path_key(shared), 20, state)
    plan.record_result(shared, 20, cid, None)
    return plan, sibs, shared, cid, state


def drain_boundary_cids(disp):
    """{(node_id, stop): cid} for every stage event the dispatcher posted."""
    out = {}
    while disp.events:
        ev = disp.events.pop()
        if ev.kind == "stage":
            out[(ev.payload["node_id"], ev.payload["stop"])] = \
                ev.payload["cid"]
    return out


# ---------------------------------------------------------------------------
# the descriptor
# ---------------------------------------------------------------------------


def test_worker_mesh_descriptor_basics():
    m = WorkerMesh.build([0, 1, 2, 3])
    assert m.n_devices == 4
    assert m.axes == (("data", 4),)
    assert m.sizes == {"data": 4}
    assert m.host == "host0"
    assert m.key == ((0, 1, 2, 3), (("data", 4),), "host0")

    m2 = WorkerMesh.build([0, 1, 2, 3], axes=(("data", 2), ("model", 2)))
    assert m2.sizes == {"data": 2, "model": 2}
    assert m2.key != m.key


def test_worker_mesh_validation():
    with pytest.raises(ValueError):
        WorkerMesh.build([])
    with pytest.raises(ValueError):
        # axis sizes must cover exactly the owned devices
        WorkerMesh.build([0, 1, 2], axes=(("data", 2),))


def test_worker_mesh_pickles():
    m = WorkerMesh.build([4, 5, 6, 7], axes=(("data", 2), ("model", 2)),
                         host="rack3")
    m2 = pickle.loads(pickle.dumps(m))
    assert m2 == m
    assert m2.key == m.key


def test_plan_worker_meshes():
    meshes = plan_worker_meshes(3, 2, host="hq")
    assert len(meshes) == 3
    assert [m.device_ids for m in meshes] == [(0, 1), (2, 3), (4, 5)]
    assert all(m.host == "hq" for m in meshes)
    # <=0 devices: a plain thread fleet
    assert plan_worker_meshes(2, 0) == (None, None)


def test_worker_width_accounting():
    assert Worker(0).devices == 1
    assert Worker(0).host == "host0"
    w = Worker(1, mesh=WorkerMesh.build([0, 1], host="h9"))
    assert w.devices == 2
    assert w.host == "h9"


# ---------------------------------------------------------------------------
# placement: hints, the gate, degradation
# ---------------------------------------------------------------------------


def test_solo_chain_takes_widest_mesh():
    """Default hint for a solo chain is "deep": devices go to sharding."""
    plan = SearchPlan()
    plan.submit(Trial(HpConfig({"lr": Constant(0.1)}), 30))
    narrow = Worker(0, mesh=WorkerMesh.build([0, 1]))
    wide = Worker(1, mesh=WorkerMesh.build([2, 3, 4, 5]))
    disp = make_dispatcher(plan, SimulatedTrainer(), [narrow, wide])
    disp.assign()
    assert not wide.idle
    assert narrow.idle
    assert disp.stats.steps_run == 30
    assert disp.stats.mesh_placements == 1
    # the mesh width is the accounting width
    assert disp.stats.gpu_seconds == pytest.approx(
        4 * (30 * 1.0 + 2.0 + 5.0))          # steps + save + eval


def test_sibling_group_takes_narrowest_mesh():
    """Default hint for a sibling group is "wide": the group already
    parallelizes across trials, so it yields the big mesh to others."""
    store = CheckpointStore()
    plan, sibs, shared, cid, _ = seeded_sibling_plan(store)
    wide = Worker(0, mesh=WorkerMesh.build([0, 1, 2, 3]))
    narrow = Worker(1, mesh=WorkerMesh.build([4, 5]))
    disp = make_dispatcher(plan, BatchedSim(), [wide, narrow], store=store,
                           batch_siblings=True)
    disp.assign()
    assert not narrow.idle
    assert wide.idle
    assert disp.stats.batched_groups == 1
    assert disp.stats.steps_run == 60        # 3 tails x 20
    assert disp.stats.mesh_placements == 1
    assert disp.stats.placement_rejections == 0


def test_incompatible_mesh_redirected_to_thread_worker():
    """The divisibility gate routes work away from meshes the backend
    cannot shard on — the old code would have dumped the group on
    ``idle[0]`` regardless."""
    store = CheckpointStore()
    plan, sibs, shared, cid, _ = seeded_sibling_plan(store)
    meshy = Worker(0, mesh=WorkerMesh.build([0, 1, 2, 3]))
    thread = Worker(1)
    disp = make_dispatcher(plan, PickySim(), [meshy, thread], store=store,
                           batch_siblings=True)
    disp.assign()
    assert meshy.idle
    assert not thread.idle
    assert disp.stats.batched_groups == 1
    assert disp.stats.placement_rejections >= 1
    assert disp.stats.mesh_placements == 0


def test_all_rejected_fleet_degrades_instead_of_starving():
    """When EVERY candidate fails the gate the narrowest mesh hosts the
    work anyway (replicated execution) — rejection must redirect, never
    wedge the plan."""
    store = CheckpointStore()
    plan, sibs, shared, cid, _ = seeded_sibling_plan(store)
    wide = Worker(0, mesh=WorkerMesh.build([0, 1, 2, 3]))
    narrow = Worker(1, mesh=WorkerMesh.build([4, 5]))
    disp = make_dispatcher(plan, PickySim(), [wide, narrow], store=store,
                           batch_siblings=True)
    disp.assign()
    assert disp.stats.steps_run == 60
    assert not narrow.idle                   # narrowest hosts it
    assert wide.idle
    assert disp.stats.placement_rejections == 2
    assert disp.stats.mesh_placements == 1


def test_homogeneous_fleet_places_first_idle():
    """Ties resolve to the earliest candidate: a homogeneous mesh fleet
    behaves exactly like the classic first-idle dispatcher."""
    plan = SearchPlan()
    plan.submit(Trial(HpConfig({"lr": Constant(0.1)}), 30))
    workers = [Worker(i, mesh=m) for i, m in enumerate(plan_worker_meshes(3, 2))]
    disp = make_dispatcher(plan, SimulatedTrainer(), workers)
    disp.assign()
    assert not workers[0].idle
    assert workers[1].idle and workers[2].idle


# ---------------------------------------------------------------------------
# dispatcher bugfixes
# ---------------------------------------------------------------------------


def test_deferred_chain_returns_worker_to_round_pool():
    """A chain deferred because its parent was truncated away must hand
    its worker back to the round — the refill then extracts other ready
    work.  The old code stranded the worker idle for the whole round."""
    plan = SearchPlan()
    t1 = Trial(HpConfig({"lr": MultiStep(0.1, [40, 80],
                                         values=[0.1, 0.05, 0.01])}), 120)
    t2 = Trial(HpConfig({"lr": MultiStep(0.1, [40, 80],
                                         values=[0.1, 0.05, 0.02])}), 120)
    other = Trial(HpConfig({"lr": Constant(0.3)}), 50)
    l1, _, _ = plan.submit(t1)
    l2, _, _ = plan.submit(t2)
    plan.submit(other)
    # profile the sibling leaves heavy so both 120-step chains outrank the
    # 50-step filler on the critical path
    plan.record_profile(l1.node_id, 10.0)
    plan.record_profile(l2.node_id, 10.0)

    disp = make_dispatcher(plan, SimulatedTrainer(), [Worker(0), Worker(1)],
                           max_steps_per_chain=40)
    disp.assign()
    # chain 1 = [A,B,C1] truncated to [A]; chain 2 = [C2] whose parent B
    # was cut -> deferred; the freed worker picks up the 50-step trial
    assert disp.stats.chains_deferred == 1
    assert disp.stats.steps_run == 90        # A (40) + other (50)
    assert all(not w.idle for w in disp.workers)


def test_sibling_resume_dedup_copies_before_fanout():
    """One resume load feeding several group members must be cloned per
    member: a backend that consumes its input in place (donation, mutable
    dict states) would otherwise corrupt its siblings' carries."""

    class ClobberingSim(BatchedSim):
        def run_stages_batched(self, states, ctxs):
            outs = []
            for s, c in zip(states, ctxs):
                outs.append(self.run_stage(s, c))
                s.clear()                    # consume the input in place
            return outs

    store = CheckpointStore()
    plan, sibs, shared, cid, fork_state = seeded_sibling_plan(store)
    # snapshot before dispatch: the in-memory store serves the seeded tree
    # by reference, and the first member is *allowed* to consume it
    fork_state = dict(fork_state)
    disp = make_dispatcher(plan, ClobberingSim(), [Worker(0)], store=store,
                           batch_siblings=True)
    disp.assign()                            # no KeyError: members got copies
    assert disp.stats.batched_groups == 1

    # and every member advanced from the *pristine* fork state
    cids = drain_boundary_cids(disp)
    ref = SimulatedTrainer()
    for t in sibs:
        leaf = plan.trial_paths[t.trial_id][-1]
        node = plan.node(leaf)
        ctx = StageContext(node_id=leaf, desc=node.desc,
                           node_start=node.start, start=20, stop=40,
                           path_key=plan.path_key(leaf))
        want = ref.run_stage(dict(fork_state), ctx)
        got = store.get(cids[(leaf, 40)])
        assert got["progress"] == want["progress"]
        assert got["step"] == 40


# ---------------------------------------------------------------------------
# d2d handoff
# ---------------------------------------------------------------------------


def resume_plan(store, progress=7.5, seed_store=True):
    """One 40-step trial checkpointed at 20 -> a single resume chain.
    Returns (plan, node_id, cid, fork_state)."""
    plan = SearchPlan()
    t = Trial(HpConfig({"lr": Constant(0.1)}), 40)
    leaf, _, _ = plan.submit(t)
    state = {"progress": progress, "step": 20}
    if seed_store:
        cid = store.put(plan.path_key(leaf.node_id), 20, state)
    else:
        cid = "d2d-only@20"
    plan.record_result(leaf.node_id, 20, cid, None)
    return plan, leaf.node_id, cid, state


def test_d2d_same_host_hit_bypasses_store():
    """A boundary state produced on the consumer's host is served from
    the device cache: the store is never asked (here it doesn't even hold
    the cid), yet clock/ckpt_loads accounting is the store path's."""
    store = CheckpointStore()
    plan, nid, cid, state = resume_plan(store, seed_store=False)
    worker = Worker(0, mesh=WorkerMesh.build([0], host="rack1"))
    disp = make_dispatcher(plan, SimulatedTrainer(), [worker], store=store)
    disp._d2d[cid] = (state, "rack1")
    disp.assign()
    assert disp.stats.d2d_handoffs == 1
    assert disp.stats.ckpt_misses == 0
    assert disp.stats.ckpt_loads == 1        # accounting identical to store
    assert disp.stats.steps_run == 20

    # the resumed computation really flowed from the handed-off state
    cids = drain_boundary_cids(disp)
    ref = SimulatedTrainer()
    node = plan.node(nid)
    ctx = StageContext(node_id=nid, desc=node.desc, node_start=node.start,
                       start=20, stop=40, path_key=plan.path_key(nid))
    want = ref.run_stage(dict(state), ctx)
    assert store.get(cids[(nid, 40)])["progress"] == want["progress"]
    # the new boundary is retained for the next same-host consumer
    assert cids[(nid, 40)] in disp._d2d


def test_d2d_cross_host_falls_back_to_store():
    store = CheckpointStore()
    plan, nid, cid, state = resume_plan(store)
    worker = Worker(0, mesh=WorkerMesh.build([0], host="rack2"))
    disp = make_dispatcher(plan, SimulatedTrainer(), [worker], store=store)
    disp._d2d[cid] = (state, "rack1")        # produced elsewhere
    disp.assign()
    assert disp.stats.d2d_handoffs == 0
    assert disp.stats.ckpt_loads == 1
    assert disp.stats.steps_run == 20


def test_d2d_backend_decline_falls_back_to_store():
    class NoTransferSim(SimulatedTrainer):
        def device_transfer(self, state, mesh):
            return None

    store = CheckpointStore()
    plan, nid, cid, state = resume_plan(store)
    worker = Worker(0, mesh=WorkerMesh.build([0], host="rack1"))
    disp = make_dispatcher(plan, NoTransferSim(), [worker], store=store)
    disp._d2d[cid] = (state, "rack1")
    disp.assign()
    assert disp.stats.d2d_handoffs == 0
    assert disp.stats.ckpt_loads == 1
    assert disp.stats.steps_run == 20


def test_d2d_disabled_on_thread_fleets():
    """Classic thread fleets never populate the device cache — their
    store-counter behavior stays bit-for-bit what it was."""
    store = CheckpointStore()
    plan, nid, cid, state = resume_plan(store)
    disp = make_dispatcher(plan, SimulatedTrainer(), [Worker(0)],
                           store=store)
    disp.assign()
    assert disp._d2d == {}
    assert disp.stats.d2d_handoffs == 0
    assert disp.stats.steps_run == 20


def test_d2d_cache_is_lru_bounded():
    store = CheckpointStore()
    plan, nid, cid, state = resume_plan(store)
    worker = Worker(0, mesh=WorkerMesh.build([0]))
    disp = make_dispatcher(plan, SimulatedTrainer(), [worker], store=store)
    for i in range(disp._d2d_cap + 5):
        disp._d2d_put(f"cid{i}", {"step": i}, worker)
    assert len(disp._d2d) == disp._d2d_cap
    assert "cid0" not in disp._d2d           # oldest evicted
    assert f"cid{disp._d2d_cap + 4}" in disp._d2d


# ---------------------------------------------------------------------------
# fleet equivalences
# ---------------------------------------------------------------------------


def _det(stats):
    """Deterministic cross-fleet view: wall timers, physical store
    counters and the mesh-plane counters themselves (d2d handoffs skip
    physical reads; placements only exist on mesh fleets)."""
    return dataclasses.replace(
        stats, ckpt_save_seconds=0.0, ckpt_load_seconds=0.0,
        ckpt_delta_bytes=0, ckpt_full_bytes=0, ckpt_logical_bytes=0,
        ckpt_bytes_written=0, ckpt_delta_commits=0, ckpt_delta_rebases=0,
        ckpt_mem_hits=0, ckpt_disk_hits=0, ckpt_remote_hits=0,
        ckpt_store_misses=0, ckpt_tier_promotions=0, ckpt_tier_demotions=0,
        ckpt_tmp_reclaimed=0, d2d_handoffs=0, mesh_placements=0)


def _grid_run(worker_meshes):
    db = SearchPlanDB()
    study = Study.create(db, "m", "d", ("lr",))
    trials = [sib_trial(v) for v in (0.05, 0.02, 0.01)] + \
             [Trial(HpConfig({"lr": Constant(0.3)}), 60)]
    eng = study.engine(SimulatedTrainer(), n_workers=3, batch_siblings=True)\
        if worker_meshes is None else \
        study.engine(SimulatedTrainer(), n_workers=3, batch_siblings=True,
                     worker_meshes=worker_meshes)
    stats = eng.run([GridTuner(trials)])
    return db.get(study.key), stats


def test_one_device_mesh_fleet_replays_thread_fleet():
    """width-1 meshes change nothing but the mesh-plane counters: the
    virtual clock, per-study breakdown, metrics and checkpoints replay the
    thread fleet exactly."""
    plan_t, stats_t = _grid_run(None)
    plan_m, stats_m = _grid_run(plan_worker_meshes(3, 1))
    assert stats_m.mesh_placements > 0
    assert stats_t.mesh_placements == 0
    assert _det(stats_m) == _det(stats_t)
    assert set(plan_m.nodes) == set(plan_t.nodes)
    for nid, node in plan_m.nodes.items():
        assert node.metrics == plan_t.nodes[nid].metrics
        assert set(node.ckpts) == set(plan_t.nodes[nid].ckpts)


def test_session_snapshot_round_trips_meshes(tmp_path):
    """Worker meshes survive snapshot/restore (session format v3) and the
    restored session finishes with the uninterrupted run's stats."""
    meshes = plan_worker_meshes(2, 2, host="hq")
    spec = StudySpec("m", "d", ("lr",))
    trials = [sib_trial(v, total=60) for v in (0.05, 0.02)]

    def fresh():
        svc = StudyService(SearchPlanDB(), SimulatedTrainer(), n_workers=2,
                           worker_meshes=meshes)
        svc.submit(spec, GridTuner(list(trials)))
        return svc

    ref = fresh().close()

    svc = fresh()
    svc.run_until(30.0)
    path = svc.snapshot(str(tmp_path / "sess.pkl"))
    svc2 = StudyService.restore(SearchPlanDB(), path, SimulatedTrainer())
    assert [w.mesh for w in svc2._engine.workers] == list(meshes)
    got = svc2.close()
    assert _det(got) == _det(ref)
    assert got.mesh_placements == ref.mesh_placements


# ---------------------------------------------------------------------------
# sharded execution is bitwise-lossless (subprocess: forced host devices)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = """
import sys
sys.path.insert(0, {src!r})
sys.path.insert(0, {tests!r})
import jax
assert jax.device_count() == 4, jax.device_count()
import numpy as np
from test_dataplane import tiny_backend, assert_states_identical
from repro.core import SearchPlanDB, Study
from repro.core.hpseq import HpConfig, MultiStep
from repro.core.trial import Trial
from repro.core.tuners import GridTuner
from repro.dist.meshes import WorkerMesh

def run(meshes):
    db = SearchPlanDB()
    study = Study.create(db, "m", "d", ("lr",))
    trials = [Trial(HpConfig({{"lr": MultiStep(0.1, [8],
                                               values=[0.1, v])}}), 16)
              for v in (0.05, 0.02, 0.01)]
    backend = tiny_backend(vectorize_groups=True)
    # one worker: the fork checkpoint lands first, so the sibling tails
    # form a ready group next round instead of chaining off in-round state
    eng = study.engine(backend, n_workers=1, batch_siblings=True,
                       worker_meshes=meshes)
    stats = eng.run([GridTuner(trials)])
    return db.get(study.key), stats, backend, eng, trials

# thread fleet reference, then one 4-device mesh per worker
plan_t, stats_t, backend_t, eng_t, trials = run(None)
mesh = WorkerMesh.build([0, 1, 2, 3])
plan_m, stats_m, backend_m, eng_m, _ = run([mesh])

assert stats_m.mesh_placements > 0, "no stage ever ran on the mesh"
assert stats_m.batched_groups >= 1, "sibling group did not batch"
assert stats_m.steps_run == stats_t.steps_run
# the backend really materialized + compiled against the mesh: the live
# Mesh is cached and mesh-keyed executables exist alongside none-keyed
assert backend_m._meshes, "set_mesh never materialized a jax Mesh"
assert any(k[0] == "fused" and k[-2] == mesh.key
           for k in backend_m._chunk_fns), "no mesh-keyed solo executable"
assert any(k[0] == "group" and k[-3] == mesh.key
           for k in backend_m._chunk_fns), "no mesh-keyed group executable"

# bitwise: every leaf checkpoint identical between the fleets
for t in trials:
    leaf = plan_m.trial_paths[t.trial_id][-1]
    cid_m = plan_m.nodes[leaf].ckpts[16]
    cid_t = plan_t.nodes[leaf].ckpts[16]
    assert_states_identical(eng_m.store.get(cid_m), eng_t.store.get(cid_t))
    assert plan_m.nodes[leaf].metrics[16] == plan_t.nodes[leaf].metrics[16]
print("SHARDED-BITWISE-OK")
"""


def test_sharded_mesh_execution_bitwise_equals_thread_fleet(tmp_path):
    """A 4-device mesh worker shards the carry (fsdp over ``data``) while
    the sibling group vmaps across trials within the mesh — and the leaf
    checkpoints are bit-identical to the unsharded thread fleet.  Runs in
    a subprocess: the forced host-device count must precede jax import."""
    script = tmp_path / "sharded_bitwise.py"
    script.write_text(_SHARDED_SCRIPT.format(
        src=os.path.join(REPO, "src"), tests=os.path.join(REPO, "tests")))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED-BITWISE-OK" in proc.stdout


def test_jax_backend_divisibility_gate():
    """The placement gate reuses the PR 3 divisibility rule via
    ``jax.eval_shape`` — no devices are materialized, so it runs on the
    default single-CPU jax."""
    from test_dataplane import tiny_backend

    tb = tiny_backend()
    four = WorkerMesh.build([0, 1, 2, 3])     # 16x4 / 4-vector shard on 4
    three = WorkerMesh.build([0, 1, 2], axes=(("data", 3),))
    assert tb.mesh_compatible(four, []) is True
    assert tb.mesh_compatible(three, []) is False   # 3 divides nothing
    assert tb.mesh_compatible(None, []) is True
    # cached per mesh key
    assert tb._mesh_ok[four.key] is True
    assert tb._mesh_ok[three.key] is False
