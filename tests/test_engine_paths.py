"""Engine corner paths: mid-chain kills, truncated-parent deferral,
trial-based salting, and checkpoint GC."""

from repro.core import SearchPlan, SearchPlanDB, Study
from repro.core.engine import Aggregator, EngineStats, EventLoop, ExecutionEngine, Tuner
from repro.core.hpseq import Constant, HpConfig, MultiStep
from repro.core.trainer import SimulatedTrainer
from repro.core.trial import Trial
from repro.core.tuners import GridTuner, SHATuner
from repro.train.checkpoint import CheckpointStore


def const_trial(v, steps):
    return Trial(HpConfig({"lr": Constant(v)}), steps)


# ---------------------------------------------------------------------------
# kill a trial while its chain is running: waiter cleanup
# ---------------------------------------------------------------------------


class KillOnFirstResult(Tuner):
    """Submits a short and a long trial on one node; kills the long one the
    moment the short result arrives (its tail stage is already running)."""

    def __init__(self, short, long):
        self.short, self.long = short, long
        self.got = []
        self._done = False

    def start(self, handle):
        self._handle = handle
        handle.submit(self.short)
        handle.submit(self.long)

    def on_result(self, trial, step, metrics):
        self.got.append((trial.trial_id, step))
        if trial.trial_id == self.short.trial_id:
            self._handle.kill(self.long)
            self._done = True

    def is_done(self):
        return self._done


def test_kill_mid_chain_cleans_waiters():
    plan = SearchPlan()
    short, long = const_trial(0.1, 50), const_trial(0.1, 150)
    eng = ExecutionEngine(plan, SimulatedTrainer(), n_workers=1)
    tuner = KillOnFirstResult(short, long)
    eng.run([tuner])
    # the long trial never observed a result after its kill
    assert all(tid != long.trial_id for tid, _ in tuner.got)
    # no wait-list entry still references the killed trial
    for ws in eng.aggregator.waiters.values():
        assert all(t.trial_id != long.trial_id for _, t in ws)
    assert plan.pending_requests() == []
    assert long.trial_id in eng.aggregator.killed


# ---------------------------------------------------------------------------
# _truncate + parent-not-produced early return in _execute_chain
# ---------------------------------------------------------------------------


def test_truncated_parent_defers_dependent_chain():
    """With a tight chain budget the shared prefix is cut before producing
    the branch's input state; the branch chain must defer to a later round
    (and the run must still complete losslessly)."""
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr",))
    trials = [
        Trial(HpConfig({"lr": Constant(0.1)}), 50),                    # cut @50
        Trial(HpConfig({"lr": MultiStep(0.1, [100], values=[0.1, 0.05])}), 200),
        Trial(HpConfig({"lr": MultiStep(0.1, [100], values=[0.1, 0.02])}), 150),
    ]
    tuner = GridTuner(trials)
    stats = st.run(tuner, SimulatedTrainer(), n_workers=2,
                   max_steps_per_chain=40)
    assert tuner.is_done()
    assert stats.chains_deferred >= 1          # the early-return fired
    plan = db.get(st.key)
    assert plan.pending_requests() == []       # deferred work was rescheduled
    for t in trials:                           # every leaf got its metrics
        leaf = plan.nodes[plan.trial_paths[t.trial_id][-1]]
        assert leaf.metrics


# ---------------------------------------------------------------------------
# share=False salting: two identical studies must not dedup
# ---------------------------------------------------------------------------


class OneShot(Tuner):
    def __init__(self, trial):
        self.trial = trial
        self._done = False

    def start(self, handle):
        handle.submit(self.trial)

    def on_result(self, trial, step, metrics):
        self._done = True

    def is_done(self):
        return self._done


def test_trial_salting_prevents_cross_study_dedup():
    trial_a, trial_b = const_trial(0.1, 100), const_trial(0.1, 100)
    assert trial_a.trial_id == trial_b.trial_id   # identical configs

    shared = SearchPlan()
    eng = ExecutionEngine(shared, SimulatedTrainer(), n_workers=2, share=True)
    eng.run([OneShot(trial_a), OneShot(trial_b)])
    assert eng.stats.steps_run == 100             # stage mode dedups

    salted = SearchPlan()
    eng2 = ExecutionEngine(salted, SimulatedTrainer(), n_workers=2, share=False)
    eng2.run([OneShot(trial_a), OneShot(trial_b)])
    assert eng2.stats.steps_run == 200            # trial mode trains twice
    roots = salted.children[None]
    assert len(roots) == 2                        # distinct salted roots
    for nid in roots:
        assert len(salted.nodes[nid].trials) == 1


# ---------------------------------------------------------------------------
# checkpoint GC
# ---------------------------------------------------------------------------


def test_kill_evicts_only_unreferenced_nodes():
    plan = SearchPlan()
    t1 = const_trial(0.1, 100)
    t2 = Trial(HpConfig({"lr": MultiStep(0.1, [100], values=[0.1, 0.05])}), 200)
    root, _, _ = plan.submit(t1)
    leaf, _, _ = plan.submit(t2)          # shares the root node with t1
    store = CheckpointStore()
    cid_root = store.put(plan.path_key(root.node_id), 100, {"w": 1})
    plan.record_result(root.node_id, 100, cid_root, {"val_acc": 0.5})
    cid_leaf = store.put(plan.path_key(leaf.node_id), 200, {"w": 2})
    plan.record_result(leaf.node_id, 200, cid_leaf, {"val_acc": 0.6})

    stats = EngineStats()
    agg = Aggregator(plan, store, stats, EventLoop())
    agg.kill(t1.trial_id)
    # root still referenced by t2 — nothing evicted
    assert stats.ckpt_evictions == 0
    assert store.contains(cid_root)

    agg.kill(t2.trial_id)
    # now both nodes are orphaned: both checkpoints reclaimed
    assert stats.ckpt_evictions == 2
    assert not store.contains(cid_root) and not store.contains(cid_leaf)
    assert root.ckpts == {} and leaf.ckpts == {}


def test_sha_run_reclaims_loser_checkpoints():
    db = SearchPlanDB()
    st = Study.create(db, "m", "d", ("lr",))
    trials = [const_trial(round(0.01 * (i + 1), 3), 120) for i in range(8)]
    tuner = SHATuner(trials, min_steps=30, max_steps=120, eta=2)
    store = CheckpointStore()
    stats = st.run(tuner, SimulatedTrainer(), n_workers=4, store=store)
    assert tuner.is_done()
    assert store.puts > 0          # the caller's (initially empty, falsy)
    #                                store must actually be the one used
    assert stats.ckpt_evictions > 0
    assert len(store) == stats.ckpt_saves - stats.ckpt_evictions
    plan = db.get(st.key)
    for node in plan.nodes.values():       # dead nodes hold no checkpoints
        if node.refcount <= 0:
            assert node.ckpts == {}
